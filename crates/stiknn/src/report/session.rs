//! Session-layer report formatting: snapshot headers and top-k
//! point-value tables for the `stiknn session` inspector (DESIGN.md
//! §9/§11), plus the server-registry table (§12).

use crate::report::table::Table;
use crate::server::SessionInfo;
use crate::session::Snapshot;

/// Human-readable header table for one decoded snapshot: engine kind,
/// whether retained rows travel with it (mutable snapshots persist
/// them; immutable ones never do), and the mutation-ledger length for
/// v3 mutable snapshots.
pub fn snapshot_info_table(snap: &Snapshot) -> String {
    let h = &snap.header;
    let mut t = Table::new(&["field", "value"]);
    t.row(&["format version".into(), h.version.to_string()]);
    t.row(&["k".into(), h.k.to_string()]);
    t.row(&["metric".into(), format!("{:?}", h.metric)]);
    t.row(&["engine".into(), h.engine.label().to_string()]);
    t.row(&[
        "mutable (train set persisted)".into(),
        if h.mutable { "yes" } else { "no" }.to_string(),
    ]);
    t.row(&[
        "retained rows".into(),
        if h.mutable { "yes" } else { "no" }.to_string(),
    ]);
    t.row(&["n (train points)".into(), h.n.to_string()]);
    t.row(&["d (features)".into(), h.d.to_string()]);
    t.row(&["tests ingested".into(), h.tests.to_string()]);
    t.row(&["ledger entries".into(), h.batches.to_string()]);
    t.row(&["mutation ledger".into(), snap.mutations.len().to_string()]);
    t.row(&["train fingerprint".into(), format!("{:016x}", h.fingerprint)]);
    format!("session snapshot:\n{}", t.render())
}

/// The server registry inspector: one row per named session —
/// resident/spilled, engine, mutability, live sizes, write revision and
/// dirtiness (`stiknn serve` prints this on the way out; `list` carries
/// the same fields as JSON). `events_dropped` is the count of events
/// evicted from the bounded event ring (`serve --event-ring N`); a
/// non-zero count gets a footer line so truncated telemetry is never
/// silent.
pub fn registry_table(infos: &[SessionInfo], events_dropped: u64) -> String {
    let mut t = Table::new(&[
        "session", "state", "engine", "mutable", "n", "tests", "rev", "dirty",
    ]);
    for i in infos {
        t.row(&[
            i.name.clone(),
            (if i.resident { "resident" } else { "spilled" }).to_string(),
            i.engine.label().to_string(),
            (if i.mutable { "yes" } else { "no" }).to_string(),
            i.n.to_string(),
            i.tests.to_string(),
            i.revision.to_string(),
            (if i.dirty { "yes" } else { "no" }).to_string(),
        ]);
    }
    let mut out = format!(
        "session registry ({} session(s)):\n{}",
        infos.len(),
        t.render()
    );
    if events_dropped > 0 {
        out.push_str(&format!(
            "\nevent ring: {events_dropped} event(s) dropped (raise --event-ring to keep more)"
        ));
    }
    out
}

/// Ranked top-k point values as an aligned table.
pub fn topk_table(entries: &[(usize, f64)], by: &str) -> String {
    let mut t = Table::new(&["rank", "train index", "value"]);
    for (rank, &(index, value)) in entries.iter().enumerate() {
        t.row(&[
            (rank + 1).to_string(),
            index.to_string(),
            format!("{value:+.4e}"),
        ]);
    }
    format!("top-{} point values (by {by}):\n{}", entries.len(), t.render())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knn::distance::Metric;
    use crate::session::{MutationOp, MutationRecord, SnapshotHeader, SnapshotPayload};

    fn sample_snapshot(mutable: bool) -> Snapshot {
        Snapshot {
            header: SnapshotHeader {
                version: 3,
                k: 5,
                metric: Metric::SqEuclidean,
                engine: crate::session::Engine::Implicit,
                mutable,
                n: 600,
                d: 2,
                fingerprint: 0xABCD,
                tests: 150,
                batches: 3,
            },
            ledger: Vec::new(),
            mutations: if mutable {
                vec![
                    MutationRecord {
                        seq: 0,
                        op: MutationOp::Add,
                        index: 600,
                        label: 1,
                    },
                    MutationRecord {
                        seq: 1,
                        op: MutationOp::Remove,
                        index: 3,
                        label: 0,
                    },
                ]
            } else {
                Vec::new()
            },
            payload: SnapshotPayload::Implicit {
                main: vec![0.0; 600],
                inter: vec![0.0; 600],
            },
        }
    }

    #[test]
    fn snapshot_table_lists_all_fields() {
        let s = snapshot_info_table(&sample_snapshot(false));
        for needle in [
            "version", "SqEuclidean", "implicit", "600", "150", "000000000000abcd",
            "mutable", "retained rows", "mutation ledger",
        ] {
            assert!(s.contains(needle), "missing {needle}:\n{s}");
        }
    }

    #[test]
    fn snapshot_table_reports_mutable_state_and_ledger_length() {
        let s = snapshot_info_table(&sample_snapshot(true));
        assert!(s.contains("yes"), "{s}");
        // mutation ledger length = 2
        let ledger_line = s
            .lines()
            .find(|l| l.contains("mutation ledger"))
            .expect("mutation ledger row");
        assert!(ledger_line.contains('2'), "{ledger_line}");
        let imm = snapshot_info_table(&sample_snapshot(false));
        let imm_line = imm
            .lines()
            .find(|l| l.contains("mutation ledger"))
            .expect("mutation ledger row");
        assert!(imm_line.contains('0'), "{imm_line}");
    }

    #[test]
    fn registry_table_lists_sessions_and_states() {
        let infos = vec![
            SessionInfo {
                name: "hot".into(),
                resident: true,
                dirty: true,
                n: 30,
                tests: 3,
                engine: crate::session::Engine::Dense,
                mutable: false,
                revision: 3,
            },
            SessionInfo {
                name: "cold".into(),
                resident: false,
                dirty: false,
                n: 31,
                tests: 5,
                engine: crate::session::Engine::Implicit,
                mutable: true,
                revision: 9,
            },
        ];
        let s = registry_table(&infos, 0);
        for needle in [
            "session registry (2 session(s))",
            "hot", "cold", "resident", "spilled", "dense", "implicit", "30", "31",
        ] {
            assert!(s.contains(needle), "missing {needle}:\n{s}");
        }
        // A clean ring adds no footer; a lossy one is called out.
        assert!(!s.contains("event ring"), "{s}");
        let lossy = registry_table(&infos, 7);
        assert!(lossy.contains("event ring: 7 event(s) dropped"), "{lossy}");
    }

    #[test]
    fn topk_table_ranks_from_one() {
        let s = topk_table(&[(7, 0.25), (2, -0.5)], "main");
        assert!(s.contains("top-2"));
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[3].starts_with('1'), "{s}");
        assert!(s.contains("+2.5000e-1") || s.contains("+2.5000e1"), "{s}");
    }
}
