//! Fuzz-harness entry points (DESIGN.md §17).
//!
//! The `fuzz/` workspace's libfuzzer targets are deliberately thin —
//! one `fuzz_target!` line each — and call into this module, so the
//! properties being fuzzed are ordinary library code: compiled by the
//! tier-1 build, replayable against the checked-in corpus by
//! `tests/fuzz_corpus_replay.rs` without any fuzzer toolchain, and
//! reusable from a plain unit test when a crasher is promoted to a
//! named regression.
//!
//! Each `check_*` function takes raw untrusted bytes and PANICS iff the
//! property it guards is violated; returning normally means "this input
//! is handled correctly" (whether it was accepted or cleanly rejected).
//!
//! Properties:
//!
//! * [`check_header_bytes`] — the snapshot header parser never panics,
//!   whatever the bytes.
//! * [`check_snapshot_bytes`] — full snapshot restore never panics; an
//!   accepted snapshot is internally consistent (header ↔ ledger ↔
//!   payload agree) and its read accessors are total.
//! * [`check_protocol_line`] — NDJSON dispatch against a live session
//!   never panics, always answers well-formed JSON with an `ok` bool,
//!   and a rejected frame leaves the session state untouched.

use crate::session::protocol::handle;
use crate::session::store::{decode, decode_header};
use crate::session::{Engine, SessionConfig, SnapshotPayload, TopBy, ValuationSession};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Snapshot header parsing on raw bytes must reject garbage with an
/// error, never a panic. (The server registry runs this parser on the
/// first 58 bytes of arbitrary files to describe spilled sessions.)
pub fn check_header_bytes(bytes: &[u8]) {
    let _ = decode_header(bytes);
}

/// Full snapshot restore on raw bytes: decoding must never panic, and
/// when it succeeds the result must be internally consistent — the
/// cheap header peek agrees with the full decode, the batch ledger sums
/// to the recorded test count, the payload matches the declared shape,
/// and every read accessor is total on it.
pub fn check_snapshot_bytes(bytes: &[u8]) {
    let Ok(snap) = decode(bytes) else {
        return; // clean rejection is a correct outcome
    };
    let h = snap.header;

    // The registry's header peek and the full decode must agree.
    let peek = decode_header(bytes).expect("decode accepted, header peek must too");
    assert_eq!(peek, h, "header peek disagrees with full decode");

    // Ledger ↔ header agreement.
    assert_eq!(snap.ledger.len() as u64, h.batches, "ledger length vs header");
    let total: u64 = snap.ledger.iter().map(|b| b.len).sum();
    assert_eq!(total, h.tests, "ledger sum vs recorded tests");

    // Payload ↔ header agreement.
    let (n, d, t) = (h.n as usize, h.d as usize, h.tests as usize);
    match &snap.payload {
        SnapshotPayload::Dense(m) => {
            assert!(!h.mutable, "dense payload flagged mutable");
            assert_eq!(m.len(), n * n, "dense payload shape");
            assert!(snap.mutations.is_empty(), "dense payload with mutations");
        }
        SnapshotPayload::Implicit { main, inter } => {
            assert_eq!(main.len(), n, "implicit main shape");
            assert_eq!(inter.len(), n, "implicit inter shape");
            if !h.mutable {
                assert!(snap.mutations.is_empty(), "implicit payload with mutations");
            }
        }
        SnapshotPayload::Mutable(p) => {
            assert!(h.mutable, "mutable payload without the header flag");
            assert_eq!(p.main.len(), n, "mutable main shape");
            assert_eq!(p.inter.len(), n, "mutable inter shape");
            assert_eq!(p.train_x.len(), n * d, "mutable train_x shape");
            assert_eq!(p.train_y.len(), n, "mutable train_y shape");
            assert_eq!(p.test_x.len(), t * d, "mutable test_x shape");
            assert_eq!(p.test_y.len(), t, "mutable test_y shape");
            for rows in [p.rank.len(), p.pos.len()] {
                assert_eq!(rows, t * n, "mutable rank/pos shape");
            }
            for rows in [p.colval.len(), p.dist.len()] {
                assert_eq!(rows, t * n, "mutable colval/dist shape");
            }
        }
    }

    // Read accessors are total on any accepted snapshot.
    let _ = snap.averaged_matrix();
    let _ = snap.point_values(TopBy::Main);
    let _ = snap.point_values(TopBy::RowSum);
    let _ = snap.top_k(3, TopBy::RowSum);
}

/// The deterministic session every protocol-fuzz input is dispatched
/// against: small (n=8, d=2, t=4 ingested), mutable, implicit engine
/// with retained rows — the configuration that accepts the widest
/// command surface (ingest, queries, values, topk, stats, metrics, AND
/// the three train-set edits), so the fuzzer can reach every dispatch
/// arm. Seeded, so a crasher file reproduces bit-identically.
pub fn baseline_session() -> ValuationSession {
    let (n, d, t) = (8usize, 2usize, 4usize);
    let mut rng = Rng::new(3);
    let train_x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let train_y: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
    let test_x: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
    let test_y: Vec<i32> = (0..t).map(|_| rng.below(2) as i32).collect();
    let cfg = SessionConfig::new(3)
        .with_engine(Engine::Implicit)
        .with_retained_rows(true)
        .with_mutable(true);
    let mut session =
        ValuationSession::new(train_x, train_y, d, cfg).expect("baseline session must build");
    session.ingest(&test_x, &test_y).expect("baseline ingest must succeed");
    session
}

/// Everything a protocol command can observably change, captured as
/// plain data so "rejected frames leave the session untouched" is one
/// equality. Values are compared bit-for-bit: an untouched session is
/// IDENTICAL, not merely equivalent.
fn observable_state(s: &ValuationSession) -> (Vec<u64>, Vec<i32>, Vec<u64>, Vec<u64>) {
    let scalars = vec![
        s.n() as u64,
        s.d() as u64,
        s.tests_seen(),
        s.revision(),
        s.fingerprint(),
        s.batches_ingested(),
        s.mutations().len() as u64,
    ];
    let (main, inter) = s.raw_point_sums();
    (
        scalars,
        s.train_labels().to_vec(),
        main.iter().map(|v| v.to_bits()).collect(),
        inter.iter().map(|v| v.to_bits()).collect(),
    )
}

/// One NDJSON frame against a fresh [`baseline_session`]: dispatch must
/// not panic, the response must render as parseable JSON carrying an
/// `ok` boolean, and an `ok:false` response implies the session state
/// is bit-identical to before the frame.
///
/// Mirrors `protocol::serve`'s framing exactly: lossy UTF-8, trimmed,
/// blank lines skipped. The `snapshot` command is skipped — it writes
/// to a caller-supplied path, and a fuzzer must not get filesystem
/// reach (its file I/O is covered by `tests/store_corruption.rs`).
pub fn check_protocol_line(raw: &[u8]) {
    let line = String::from_utf8_lossy(raw);
    let trimmed = line.trim();
    if trimmed.is_empty() {
        return;
    }
    if let Ok(v) = Json::parse(trimmed) {
        if v.get("cmd").and_then(Json::as_str) == Some("snapshot") {
            return;
        }
    }

    let mut session = baseline_session();
    let before = observable_state(&session);
    let (response, _shutdown) = handle(&mut session, trimmed);

    let rendered = response.to_string();
    let reparsed = Json::parse(&rendered)
        .unwrap_or_else(|e| panic!("response is not valid JSON ({e}): {rendered}"));
    let ok = reparsed
        .get("ok")
        .and_then(Json::as_bool)
        .unwrap_or_else(|| panic!("response lacks an 'ok' bool: {rendered}"));

    if !ok {
        assert_eq!(
            before,
            observable_state(&session),
            "rejected frame mutated session state: {trimmed}"
        );
    }
}
