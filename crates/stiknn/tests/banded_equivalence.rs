//! Integration: the row-banded assembly engine is equivalent to the
//! legacy test-sharded engine and the single-threaded reference — the
//! acceptance contract of the O(W·n²) → O(n²) coordinator rework.
//!
//! Matrix of cases: worker counts {1, 2, 7} × band sizes that do NOT
//! divide n evenly (plus auto-balanced bands), against both comparison
//! targets, at ≤ 1e-12. The banded engine is additionally held to a
//! STRICTER bar — bitwise equality with single-threaded `sti_knn` — since
//! band boundaries cannot reorder any accumulator cell's `row[j] += v`
//! sequence (shapley::sti_knn::sweep_band's contract).

use stiknn::coordinator::{run_job, Assembly, ValuationJob};
use stiknn::data::{load_dataset, Dataset};
use stiknn::shapley::sti_knn::{sti_knn, StiParams};
use stiknn::util::matrix::Matrix;

fn reference(name: &str, n: usize, t: usize, seed: u64, k: usize) -> (Dataset, Matrix) {
    let ds = load_dataset(name, n, t, seed).unwrap();
    let phi = sti_knn(
        &ds.train_x,
        &ds.train_y,
        ds.d,
        &ds.test_x,
        &ds.test_y,
        &StiParams::new(k),
    );
    (ds, phi)
}

#[test]
fn banded_matches_sharded_and_single_threaded() {
    // n = 83 is prime: NO band size divides it evenly.
    let k = 4;
    let (ds, single) = reference("cpu", 83, 29, 11, k);
    for workers in [1usize, 2, 7] {
        // sharded comparator at this worker count
        let sharded = run_job(
            &ds,
            &ValuationJob::new(k)
                .with_workers(workers)
                .with_block_size(8)
                .with_assembly(Assembly::TestSharded),
        )
        .unwrap();
        assert!(
            sharded.phi.max_abs_diff(&single) < 1e-12,
            "sharded vs single-threaded, workers={workers}"
        );
        // band sizes that don't divide n=83: 10 (9 bands, last short),
        // 27 (4 bands, last short), 80 (2 bands, very uneven), 0 (auto)
        for band_rows in [10usize, 27, 80, 0] {
            let banded = run_job(
                &ds,
                &ValuationJob::new(k)
                    .with_workers(workers)
                    .with_block_size(8)
                    .with_band_rows(band_rows),
            )
            .unwrap();
            assert_eq!(banded.weight, 29.0);
            assert!(
                banded.phi.max_abs_diff(&sharded.phi) < 1e-12,
                "banded vs sharded: workers={workers} band_rows={band_rows}"
            );
            assert!(
                banded.phi.max_abs_diff(&single) < 1e-12,
                "banded vs single-threaded: workers={workers} band_rows={band_rows}"
            );
            // the stricter banded guarantee: BITWISE equality with the
            // single-threaded engine, any workers / bands / blocks
            for (a, b) in single.data().iter().zip(banded.phi.data()) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "banded not bit-identical: workers={workers} band_rows={band_rows}"
                );
            }
        }
    }
}

#[test]
fn banded_handles_more_workers_than_blocks_and_tiny_bands() {
    // degenerate shapes: 1 test block, band per row, workers >> work
    let k = 3;
    let (ds, single) = reference("moon", 17, 5, 3, k);
    let res = run_job(
        &ds,
        &ValuationJob::new(k)
            .with_workers(7)
            .with_block_size(64) // one block holds the whole test set
            .with_band_rows(1), // 17 bands of a single row each
    )
    .unwrap();
    assert_eq!(res.blocks, 1);
    for (a, b) in single.data().iter().zip(res.phi.data()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn banded_block_size_one_streams_per_test_point() {
    let k = 5;
    let (ds, single) = reference("click", 40, 13, 9, k);
    let res = run_job(
        &ds,
        &ValuationJob::new(k)
            .with_workers(2)
            .with_block_size(1) // 13 single-point blocks through the reorder buffer
            .with_band_rows(11),
    )
    .unwrap();
    assert_eq!(res.blocks, 13);
    for (a, b) in single.data().iter().zip(res.phi.data()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
