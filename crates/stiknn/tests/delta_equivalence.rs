//! Property tests for the delta subsystem (DESIGN.md §11): any
//! interleaving of {add, remove, relabel, ingest} on a mutable session
//! must leave it EXACTLY where a from-scratch session over the final
//! training set (ingesting the same test stream) would be — bit-identical
//! per-point values and retained-row queries, ≤ 1e-12 against the dense
//! n×n reference — and bit-identical across repair worker counts.

use stiknn::session::{Engine, SessionConfig, TopBy, ValuationSession};
use stiknn::shapley::sti_knn::sti_knn;
use stiknn::shapley::StiParams;
use stiknn::util::rng::Rng;

fn mutable_config(k: usize) -> SessionConfig {
    SessionConfig::new(k)
        .with_engine(Engine::Implicit)
        .with_retained_rows(true)
        .with_mutable(true)
}

fn random_problem(
    seed: u64,
    n: usize,
    d: usize,
    t: usize,
) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    (
        (0..n * d).map(|_| rng.normal() as f32).collect(),
        (0..n).map(|_| rng.below(2) as i32).collect(),
        (0..t * d).map(|_| rng.normal() as f32).collect(),
        (0..t).map(|_| rng.below(2) as i32).collect(),
    )
}

/// From-scratch comparator: a fresh mutable session over `train`,
/// ingesting the whole accumulated test stream in one batch (per-element
/// addition order is test order regardless of batching, so this is the
/// canonical reference).
fn fresh_session(
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    k: usize,
) -> ValuationSession {
    let mut s =
        ValuationSession::new(train_x.to_vec(), train_y.to_vec(), d, mutable_config(k)).unwrap();
    if !test_y.is_empty() {
        s.ingest(test_x, test_y).unwrap();
    }
    s
}

/// Bitwise state equality: per-point values under both rankings, plus
/// every retained-row pair query.
fn assert_bit_equal(live: &ValuationSession, reference: &ValuationSession, tag: &str) {
    let n = live.n();
    assert_eq!(n, reference.n(), "{tag}: n");
    assert_eq!(live.tests_seen(), reference.tests_seen(), "{tag}: tests");
    if live.tests_seen() == 0 {
        return;
    }
    for by in [TopBy::Main, TopBy::RowSum] {
        let a = live.point_values(by).unwrap();
        let b = reference.point_values(by).unwrap();
        for i in 0..n {
            assert_eq!(
                a[i].to_bits(),
                b[i].to_bits(),
                "{tag}: {by:?}[{i}] {} vs {}",
                a[i],
                b[i]
            );
        }
    }
    for i in 0..n {
        for j in 0..n {
            let a = live.cell(i, j).unwrap();
            let b = reference.cell(i, j).unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "{tag}: cell({i},{j})");
        }
    }
}

/// ≤ 1e-12 agreement with the dense O(t·n²) engine on the same data.
#[allow(clippy::too_many_arguments)]
fn assert_matches_dense(
    live: &ValuationSession,
    train_x: &[f32],
    train_y: &[i32],
    d: usize,
    test_x: &[f32],
    test_y: &[i32],
    k: usize,
    tag: &str,
) {
    let m = sti_knn(train_x, train_y, d, test_x, test_y, &StiParams::new(k));
    let n = train_y.len();
    let main = live.point_values(TopBy::Main).unwrap();
    let rowsum = live.point_values(TopBy::RowSum).unwrap();
    for i in 0..n {
        assert!(
            (main[i] - m.get(i, i)).abs() < 1e-12,
            "{tag}: main[{i}] {} vs {}",
            main[i],
            m.get(i, i)
        );
        let direct: f64 = m.row(i).iter().sum();
        assert!(
            (rowsum[i] - direct).abs() < 1e-12,
            "{tag}: rowsum[{i}] {} vs {direct}",
            rowsum[i]
        );
        for j in 0..n {
            let c = live.cell(i, j).unwrap();
            assert!(
                (c - m.get(i, j)).abs() < 1e-12,
                "{tag}: cell({i},{j}) {c} vs {}",
                m.get(i, j)
            );
        }
    }
}

#[test]
fn mutable_session_without_edits_matches_plain_retained_implicit_bits() {
    let (tx, ty, qx, qy) = random_problem(17, 16, 3, 11);
    let plain_cfg = SessionConfig::new(4)
        .with_engine(Engine::Implicit)
        .with_retained_rows(true);
    let mut plain = ValuationSession::new(tx.clone(), ty.clone(), 3, plain_cfg).unwrap();
    let mut live = ValuationSession::new(tx, ty, 3, mutable_config(4)).unwrap();
    for (lo, hi) in [(0usize, 1usize), (1, 6), (6, 11)] {
        plain.ingest(&qx[lo * 3..hi * 3], &qy[lo..hi]).unwrap();
        live.ingest(&qx[lo * 3..hi * 3], &qy[lo..hi]).unwrap();
    }
    assert_bit_equal(&live, &plain, "no-edit mutable vs plain retained");
    for i in 0..16 {
        let a = live.row(i).unwrap();
        let b = plain.row(i).unwrap();
        for j in 0..16 {
            assert_eq!(a[j].to_bits(), b[j].to_bits(), "row({i})[{j}]");
        }
    }
}

#[test]
fn single_edits_match_from_scratch_and_dense() {
    let (tx, ty, qx, qy) = random_problem(23, 13, 2, 7);
    let k = 3;

    // --- add (including a duplicate-feature point: tie stress) ---
    for (tag, new_x, new_y) in [
        ("add-random", vec![0.3f32, -0.8], 1),
        ("add-dup", tx[6 * 2..7 * 2].to_vec(), 0),
    ] {
        let mut live = ValuationSession::new(tx.clone(), ty.clone(), 2, mutable_config(k)).unwrap();
        live.ingest(&qx, &qy).unwrap();
        let id = live.add_train(&new_x, new_y).unwrap();
        assert_eq!(id, 13);
        assert_eq!(live.n(), 14);
        assert_eq!(live.mutations().len(), 1);
        let mut train_x = tx.clone();
        train_x.extend_from_slice(&new_x);
        let mut train_y = ty.clone();
        train_y.push(new_y);
        let reference = fresh_session(&train_x, &train_y, 2, &qx, &qy, k);
        assert_bit_equal(&live, &reference, tag);
        assert_matches_dense(&live, &train_x, &train_y, 2, &qx, &qy, k, tag);
    }

    // --- remove ---
    let mut live = ValuationSession::new(tx.clone(), ty.clone(), 2, mutable_config(k)).unwrap();
    live.ingest(&qx, &qy).unwrap();
    live.remove_train(5).unwrap();
    assert_eq!(live.n(), 12);
    let mut train_x = tx.clone();
    train_x.drain(5 * 2..6 * 2);
    let mut train_y = ty.clone();
    train_y.remove(5);
    let reference = fresh_session(&train_x, &train_y, 2, &qx, &qy, k);
    assert_bit_equal(&live, &reference, "remove");
    assert_matches_dense(&live, &train_x, &train_y, 2, &qx, &qy, k, "remove");

    // --- relabel ---
    let mut live = ValuationSession::new(tx.clone(), ty.clone(), 2, mutable_config(k)).unwrap();
    live.ingest(&qx, &qy).unwrap();
    live.relabel_train(2, 1 - ty[2]).unwrap();
    let mut train_y = ty.clone();
    train_y[2] = 1 - ty[2];
    let reference = fresh_session(&tx, &train_y, 2, &qx, &qy, k);
    assert_bit_equal(&live, &reference, "relabel");
    assert_matches_dense(&live, &tx, &train_y, 2, &qx, &qy, k, "relabel");
}

#[test]
fn edits_before_any_ingest_work() {
    let (tx, ty, qx, qy) = random_problem(31, 10, 2, 5);
    let mut live = ValuationSession::new(tx.clone(), ty.clone(), 2, mutable_config(2)).unwrap();
    // edit an EMPTY session, then ingest: repairs over zero rows
    live.remove_train(0).unwrap();
    live.add_train(&[0.5, 0.5], 1).unwrap();
    live.ingest(&qx, &qy).unwrap();
    let mut train_x = tx.clone();
    train_x.drain(0..2);
    train_x.extend_from_slice(&[0.5, 0.5]);
    let mut train_y = ty.clone();
    train_y.remove(0);
    train_y.push(1);
    let reference = fresh_session(&train_x, &train_y, 2, &qx, &qy, 2);
    assert_bit_equal(&live, &reference, "edit-then-first-ingest");
}

/// The headline property: random interleavings of
/// {add, remove, relabel, ingest} — including duplicate-distance points
/// and k-boundary crossings — end (and stay, at every checkpoint)
/// bit-identical to from-scratch over the evolving train set.
#[test]
fn random_interleavings_match_from_scratch() {
    let d = 2;
    let k = 3;
    for seed in [1u64, 2, 3] {
        let mut rng = Rng::new(0xDE17A + seed);
        let (tx, ty, _, _) = random_problem(seed, 12, d, 1);
        let mut train_x = tx;
        let mut train_y = ty;
        let mut test_x: Vec<f32> = Vec::new();
        let mut test_y: Vec<i32> = Vec::new();
        let mut live = ValuationSession::new(
            train_x.clone(),
            train_y.clone(),
            d,
            mutable_config(k),
        )
        .unwrap();

        for step in 0..24 {
            let n = train_y.len();
            match rng.below(4) {
                0 => {
                    // add: half the time a DUPLICATE of an existing row
                    // (duplicate distances → tie-break stress)
                    let (x, y) = if rng.below(2) == 0 {
                        let src = rng.below(n);
                        (
                            train_x[src * d..(src + 1) * d].to_vec(),
                            rng.below(2) as i32,
                        )
                    } else {
                        (
                            (0..d).map(|_| rng.normal() as f32).collect(),
                            rng.below(2) as i32,
                        )
                    };
                    let id = live.add_train(&x, y).unwrap();
                    assert_eq!(id, n);
                    train_x.extend_from_slice(&x);
                    train_y.push(y);
                }
                1 => {
                    // remove, unless that would cross the k/2 floor —
                    // then the edit must FAIL cleanly and change nothing
                    let i = rng.below(n);
                    if n - 1 >= k && n - 1 >= 2 {
                        live.remove_train(i).unwrap();
                        train_x.drain(i * d..(i + 1) * d);
                        train_y.remove(i);
                    } else {
                        let before = live.point_values(TopBy::RowSum);
                        assert!(live.remove_train(i).is_err(), "seed={seed} step={step}");
                        assert_eq!(
                            live.point_values(TopBy::RowSum),
                            before,
                            "failed edit must not change state"
                        );
                    }
                }
                2 => {
                    let i = rng.below(n);
                    let y = rng.below(2) as i32;
                    live.relabel_train(i, y).unwrap();
                    train_y[i] = y;
                }
                _ => {
                    let batch = 1 + rng.below(3);
                    let bx: Vec<f32> =
                        (0..batch * d).map(|_| rng.normal() as f32).collect();
                    let by: Vec<i32> = (0..batch).map(|_| rng.below(2) as i32).collect();
                    live.ingest(&bx, &by).unwrap();
                    test_x.extend_from_slice(&bx);
                    test_y.extend_from_slice(&by);
                }
            }
            // checkpoint every few steps (and always at the end)
            if step % 6 == 5 || step == 23 {
                let reference =
                    fresh_session(&train_x, &train_y, d, &test_x, &test_y, k);
                assert_bit_equal(&live, &reference, &format!("seed={seed} step={step}"));
                if !test_y.is_empty() {
                    assert_matches_dense(
                        &live,
                        &train_x,
                        &train_y,
                        d,
                        &test_x,
                        &test_y,
                        k,
                        &format!("dense seed={seed} step={step}"),
                    );
                }
            }
        }
        assert_eq!(live.mutations().len() as u64, {
            // every successful edit got a monotone seq
            live.mutations().last().map(|m| m.seq + 1).unwrap_or(0)
        });
    }
}

#[test]
fn k_boundary_floor_is_enforced() {
    // n = 4, k = 3: one removal is legal (n→3 == k), the next must fail
    let (tx, ty, qx, qy) = random_problem(41, 4, 2, 6);
    let mut live = ValuationSession::new(tx, ty, 2, mutable_config(3)).unwrap();
    live.ingest(&qx, &qy).unwrap();
    live.remove_train(0).unwrap();
    assert_eq!(live.n(), 3);
    let err = live.remove_train(0).unwrap_err().to_string();
    assert!(err.contains("below k"), "unhelpful error: {err}");
    // the 2-point floor, independent of k
    let (tx, ty, _, _) = random_problem(43, 3, 2, 1);
    let mut live = ValuationSession::new(tx, ty, 2, mutable_config(1)).unwrap();
    live.remove_train(0).unwrap();
    let err = live.remove_train(0).unwrap_err().to_string();
    assert!(err.contains("at least 2"), "unhelpful error: {err}");
}

#[test]
fn repairs_are_bit_identical_across_worker_counts() {
    let (tx, ty, qx, qy) = random_problem(53, 18, 3, 20);
    // parallel_min(1) forces the repair fan-out onto the worker pool;
    // the high-parallel_min session repairs single-threaded
    let serial_cfg = mutable_config(4).with_parallel_min(10_000);
    let fanout_cfg = mutable_config(4).with_parallel_min(1).with_workers(3);
    let mut serial = ValuationSession::new(tx.clone(), ty.clone(), 3, serial_cfg).unwrap();
    let mut fanout = ValuationSession::new(tx, ty, 3, fanout_cfg).unwrap();
    for s in [&mut serial, &mut fanout] {
        s.ingest(&qx, &qy).unwrap();
        s.add_train(&[0.1, 0.2, 0.3], 1).unwrap();
        s.remove_train(4).unwrap();
        s.relabel_train(2, 1).unwrap();
    }
    assert_bit_equal(&fanout, &serial, "worker fan-out");
}

#[test]
fn mutation_edits_are_refused_on_immutable_sessions() {
    let (tx, ty, _, _) = random_problem(61, 8, 2, 1);
    // plain implicit+retained (not mutable)
    let cfg = SessionConfig::new(2)
        .with_engine(Engine::Implicit)
        .with_retained_rows(true);
    let mut s = ValuationSession::new(tx.clone(), ty.clone(), 2, cfg).unwrap();
    for err in [
        s.add_train(&[0.0, 0.0], 0).unwrap_err().to_string(),
        s.remove_train(0).unwrap_err().to_string(),
        s.relabel_train(0, 1).unwrap_err().to_string(),
    ] {
        assert!(err.contains("mutable"), "unhelpful error: {err}");
    }
    // config validation: mutable without implicit+retained is rejected
    assert!(ValuationSession::new(
        tx.clone(),
        ty.clone(),
        2,
        SessionConfig::new(2).with_mutable(true)
    )
    .is_err());
    assert!(ValuationSession::new(
        tx,
        ty,
        2,
        SessionConfig::new(2)
            .with_engine(Engine::Implicit)
            .with_mutable(true)
    )
    .is_err());
}

#[test]
fn bad_edit_inputs_are_rejected_cleanly() {
    let (tx, ty, qx, qy) = random_problem(67, 9, 2, 4);
    let mut s = ValuationSession::new(tx, ty, 2, mutable_config(2)).unwrap();
    s.ingest(&qx, &qy).unwrap();
    let before = s.point_values(TopBy::RowSum);
    assert!(s.add_train(&[0.1], 0).is_err(), "wrong d");
    assert!(s.add_train(&[f32::NAN, 0.0], 0).is_err(), "NaN feature");
    assert!(s.add_train(&[f32::INFINITY, 0.0], 0).is_err(), "inf feature");
    assert!(s.remove_train(9).is_err(), "index out of range");
    assert!(s.relabel_train(9, 0).is_err(), "index out of range");
    assert_eq!(s.point_values(TopBy::RowSum), before, "state unchanged");
    assert!(s.mutations().is_empty(), "failed edits must not be ledgered");
}

#[test]
fn v3_snapshot_roundtrip_mid_interleaving_is_bit_identical() {
    let (tx, ty, qx, qy) = random_problem(71, 12, 2, 10);
    let k = 3;
    let path = std::env::temp_dir().join(format!(
        "stiknn_delta_roundtrip_{}.snap",
        std::process::id()
    ));

    // uninterrupted: ingest → edits → ingest → edit
    let mut whole = ValuationSession::new(tx.clone(), ty.clone(), 2, mutable_config(k)).unwrap();
    whole.ingest(&qx[..6 * 2], &qy[..6]).unwrap();
    whole.add_train(&[0.7, -0.7], 1).unwrap();
    whole.remove_train(3).unwrap();
    whole.ingest(&qx[6 * 2..], &qy[6..]).unwrap();
    whole.relabel_train(0, 1).unwrap();

    // interrupted twin: snapshot + restore between the edits
    let mut first = ValuationSession::new(tx, ty, 2, mutable_config(k)).unwrap();
    first.ingest(&qx[..6 * 2], &qy[..6]).unwrap();
    first.add_train(&[0.7, -0.7], 1).unwrap();
    first.remove_train(3).unwrap();
    first.save(&path).unwrap();
    let mut resumed = ValuationSession::restore_mutable(&path, mutable_config(k)).unwrap();
    assert_eq!(resumed.mutations(), first.mutations());
    assert_eq!(resumed.tests_seen(), 6);
    resumed.ingest(&qx[6 * 2..], &qy[6..]).unwrap();
    resumed.relabel_train(0, 1).unwrap();
    assert_bit_equal(&resumed, &whole, "snapshot mid-interleaving");
    // ledgers continue across the restore
    assert_eq!(resumed.mutations().len(), 3);
    assert_eq!(resumed.mutations().last().unwrap().seq, 2);

    // a v3 mutable snapshot is refused by the immutable restore path...
    first.save(&path).unwrap();
    let (tx2, ty2, _, _) = random_problem(71, 12, 2, 1);
    let err = ValuationSession::restore(&path, tx2, ty2, 2, SessionConfig::new(k))
        .unwrap_err()
        .to_string();
    assert!(err.contains("restore_mutable") || err.contains("mutable"), "{err}");

    // ...and restore_mutable refuses a NON-mutable snapshot
    let (tx3, ty3, qx3, qy3) = random_problem(73, 8, 2, 3);
    let mut plain = ValuationSession::new(tx3, ty3, 2, SessionConfig::new(2)).unwrap();
    plain.ingest(&qx3, &qy3).unwrap();
    plain.save(&path).unwrap();
    let err = ValuationSession::restore_mutable(&path, mutable_config(2))
        .unwrap_err()
        .to_string();
    assert!(err.contains("not a mutable"), "{err}");

    let _ = std::fs::remove_file(&path);
}

#[test]
fn mutable_snapshot_header_reports_mutable_and_ledger() {
    let (tx, ty, qx, qy) = random_problem(79, 10, 2, 5);
    let path = std::env::temp_dir().join(format!(
        "stiknn_delta_header_{}.snap",
        std::process::id()
    ));
    let mut s = ValuationSession::new(tx, ty, 2, mutable_config(3)).unwrap();
    s.ingest(&qx, &qy).unwrap();
    s.add_train(&[0.0, 0.0], 1).unwrap();
    s.relabel_train(1, 0).unwrap();
    s.save(&path).unwrap();
    let snap = stiknn::session::store::read_snapshot(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert!(snap.header.mutable);
    assert_eq!(snap.header.engine, Engine::Implicit);
    assert_eq!(snap.header.n, 11);
    assert_eq!(snap.header.tests, 5);
    assert_eq!(snap.mutations.len(), 2);
    assert_eq!(
        snap.mutations[0].op,
        stiknn::session::MutationOp::Add
    );
    assert_eq!(snap.mutations[1].op, stiknn::session::MutationOp::Relabel);
    // values are readable straight off the snapshot
    assert!(snap.point_values(TopBy::Main).unwrap().len() == 11);
}
