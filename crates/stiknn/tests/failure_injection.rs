//! Failure injection: corrupt artifacts, malformed manifests, and
//! mid-pipeline errors must fail fast with actionable errors — never
//! hang, never return partial results silently.

use std::path::PathBuf;

use stiknn::coordinator::{run_job_with_engine, ValuationJob};
use stiknn::data::load_dataset;
use stiknn::runtime::{Engine, Manifest, StiExecutor};

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("stiknn_failure_tests").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn write_manifest(dir: &PathBuf, entries: &str) {
    std::fs::write(
        dir.join("manifest.json"),
        format!(
            r#"{{"version":1,"interchange":"hlo-text","artifacts":[{entries}]}}"#
        ),
    )
    .unwrap();
}

#[test]
fn corrupt_hlo_text_fails_at_compile_not_execute() {
    let dir = tmpdir("corrupt_hlo");
    std::fs::write(dir.join("bad.hlo.txt"), "HloModule garbage\n%%%not hlo%%%").unwrap();
    write_manifest(
        &dir,
        r#"{"name":"sti_bad","file":"bad.hlo.txt","program":"sti","n":8,"d":2,"b":2,"k":3}"#,
    );
    let manifest = Manifest::load(&dir).unwrap();
    let spec = manifest.find("sti", 8, 2, 3).unwrap();
    let err = StiExecutor::new(&manifest, spec);
    assert!(err.is_err(), "corrupt HLO must not compile");
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("bad.hlo.txt") || msg.contains("sti_bad"), "{msg}");
}

#[test]
fn truncated_manifest_is_rejected() {
    let dir = tmpdir("truncated");
    std::fs::write(dir.join("manifest.json"), r#"{"version":1,"interch"#).unwrap();
    let err = Manifest::load(&dir);
    assert!(err.is_err());
}

#[test]
fn manifest_missing_fields_rejected() {
    let dir = tmpdir("missing_fields");
    std::fs::write(dir.join("x.hlo.txt"), "HloModule x").unwrap();
    write_manifest(&dir, r#"{"name":"x","file":"x.hlo.txt","program":"sti","n":8}"#);
    let err = Manifest::load(&dir);
    assert!(err.is_err());
    assert!(format!("{:#}", err.err().unwrap()).contains("'d'"));
}

#[test]
fn xla_job_with_corrupt_artifact_fails_fast_without_hanging() {
    // end-to-end: the coordinator must surface the compile error from a
    // worker thread and terminate (fail fast), not deadlock
    let dir = tmpdir("pipeline_corrupt");
    std::fs::write(dir.join("bad.hlo.txt"), "not even hlo").unwrap();
    write_manifest(
        &dir,
        r#"{"name":"sti_bad","file":"bad.hlo.txt","program":"sti","n":50,"d":2,"b":4,"k":3}"#,
    );
    let ds = load_dataset("moon", 50, 12, 3).unwrap();
    let job = ValuationJob::new(3).with_engine(Engine::Xla).with_workers(2);
    let start = std::time::Instant::now();
    let res = run_job_with_engine(&ds, &job, &dir);
    assert!(res.is_err(), "corrupt artifact must fail the job");
    assert!(
        start.elapsed() < std::time::Duration::from_secs(30),
        "fail-fast took too long"
    );
}

#[test]
fn shape_mismatch_is_detected_before_execution() {
    // a valid artifact asked to run the wrong train size must refuse
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let spec = manifest.find("sti", 32, 2, 3).unwrap();
    let exec = StiExecutor::new(&manifest, spec).unwrap();
    // wrong n
    let bad = exec.run_block(&[0.0; 20 * 2], &[0; 20], &[0.0; 2], &[0]);
    let msg = format!("{:#}", bad.err().expect("shape mismatch must error"));
    assert!(msg.contains("does not match artifact"), "{msg}");
    // oversized test block
    let bad = exec.run_block(&[0.0; 32 * 2], &[0; 32], &[0.0; 9 * 2], &[0; 9]);
    let msg = format!("{:#}", bad.err().expect("block overflow must error"));
    assert!(msg.contains("out of range"), "{msg}");
    // empty test block
    let bad = exec.run_block(&[0.0; 32 * 2], &[0; 32], &[], &[]);
    assert!(bad.is_err());
}

#[test]
fn wrong_program_type_is_refused() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let spec = manifest.find("knn_shapley", 64, 2, 5).unwrap();
    let exec = StiExecutor::new(&manifest, spec).unwrap();
    let bad = exec.run_block(&[0.0; 64 * 2], &[0; 64], &[0.0; 2], &[0]);
    assert!(format!("{:#}", bad.err().unwrap()).contains("run_block on a knn_shapley"));
}
