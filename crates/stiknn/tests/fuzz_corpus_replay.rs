//! Replay the checked-in fuzz corpus (`fuzz/corpus/**`) through the
//! same `stiknn::verify` entry points the libfuzzer targets call — so
//! every seed and every promoted crasher runs under plain `cargo test`,
//! with no fuzzer toolchain, on every tier-1 run (DESIGN.md §17).
//!
//! The named tests below are the regression half of the contract: each
//! pins one corruption class with bytes built in-process (so they hold
//! even if the corpus directory is pruned), asserting not just
//! "no panic" but the specific rejection decode must produce.

use std::path::{Path, PathBuf};

use stiknn::bench::workspace_root_from;
use stiknn::session::store::{decode, fnv1a, MAGIC};
use stiknn::session::{SessionConfig, ValuationSession};
use stiknn::util::rng::Rng;
use stiknn::verify::{baseline_session, check_protocol_line, check_snapshot_bytes};

fn corpus_dir(target: &str) -> PathBuf {
    workspace_root_from(Path::new(env!("CARGO_MANIFEST_DIR")))
        .join("fuzz")
        .join("corpus")
        .join(target)
}

fn corpus_files(target: &str) -> Vec<(String, Vec<u8>)> {
    let dir = corpus_dir(target);
    let mut out = Vec::new();
    let entries = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("fuzz corpus dir {} must exist: {e}", dir.display()));
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_file() {
            let name = entry.file_name().to_string_lossy().into_owned();
            let bytes = std::fs::read(&path).unwrap();
            out.push((name, bytes));
        }
    }
    out.sort();
    assert!(
        out.len() >= 10,
        "{target} corpus looks gutted ({} files) — the fuzz smoke leg \
         depends on these seeds",
        out.len()
    );
    out
}

#[test]
fn snapshot_corpus_replays_clean() {
    for (name, bytes) in corpus_files("snapshot_restore") {
        // A panic here is the failure; names make the culprit obvious.
        println!("replaying snapshot seed {name} ({} bytes)", bytes.len());
        check_snapshot_bytes(&bytes);
        // Seeds are named valid-* iff decode must accept them.
        let accepted = decode(&bytes).is_ok();
        assert_eq!(
            accepted,
            name.starts_with("valid-"),
            "{name}: decode accepted={accepted} disagrees with the seed's name"
        );
    }
}

#[test]
fn protocol_corpus_replays_clean() {
    for (name, bytes) in corpus_files("protocol_dispatch") {
        println!("replaying protocol seed {name} ({} bytes)", bytes.len());
        check_protocol_line(&bytes);
    }
}

/// A real snapshot to corrupt: the same shape the corpus generator
/// uses, but produced by the actual encoder so these tests stay valid
/// if the wire format ever moves.
fn real_snapshot_bytes() -> Vec<u8> {
    let (n, d, t) = (6usize, 2usize, 3usize);
    let mut rng = Rng::new(11);
    let tx: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let ty: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
    let qx: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
    let qy: Vec<i32> = (0..t).map(|_| rng.below(2) as i32).collect();
    let mut s = ValuationSession::new(tx, ty, d, SessionConfig::new(2)).unwrap();
    s.ingest(&qx, &qy).unwrap();
    let path = std::env::temp_dir().join(format!("stiknn_replay_{}.snap", std::process::id()));
    s.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    bytes
}

/// Re-seal a corrupted body with a fresh FNV trailer so decode gets
/// past the checksum and exercises the deeper validation under test.
fn reseal(mut body: Vec<u8>) -> Vec<u8> {
    let sum = fnv1a(&body);
    body.extend_from_slice(&sum.to_le_bytes());
    body
}

#[test]
fn regression_truncation_is_rejected_at_every_length() {
    let bytes = real_snapshot_bytes();
    for keep in [0, 5, 30, 56, 64, bytes.len() / 2, bytes.len() - 1] {
        let cut = &bytes[..keep];
        check_snapshot_bytes(cut);
        let err = format!("{:#}", decode(cut).unwrap_err());
        assert!(
            err.contains("short") || err.contains("checksum") || err.contains("truncated"),
            "truncation to {keep} gave an unhelpful error: {err}"
        );
    }
}

#[test]
fn regression_flipped_byte_fails_the_checksum() {
    let bytes = real_snapshot_bytes();
    for at in [8, bytes.len() / 2, bytes.len() - 1] {
        let mut bad = bytes.clone();
        bad[at] ^= 0x40;
        check_snapshot_bytes(&bad);
        let err = format!("{:#}", decode(&bad).unwrap_err());
        assert!(
            err.contains("checksum"),
            "flip@{at} should fail the checksum, got: {err}"
        );
    }
}

#[test]
fn regression_wrong_magic_is_rejected_after_the_checksum() {
    let bytes = real_snapshot_bytes();
    let mut body = bytes[..bytes.len() - 8].to_vec();
    body[..8].copy_from_slice(b"NOTASNAP");
    let bad = reseal(body);
    check_snapshot_bytes(&bad);
    let err = format!("{:#}", decode(&bad).unwrap_err());
    assert!(err.contains("magic"), "expected a magic error, got: {err}");
}

#[test]
fn regression_future_version_is_rejected() {
    let bytes = real_snapshot_bytes();
    let mut body = bytes[..bytes.len() - 8].to_vec();
    body[8..12].copy_from_slice(&9u32.to_le_bytes());
    let bad = reseal(body);
    check_snapshot_bytes(&bad);
    let err = format!("{:#}", decode(&bad).unwrap_err());
    assert!(err.contains("version"), "expected a version error, got: {err}");
}

#[test]
fn regression_unknown_tags_are_rejected() {
    let bytes = real_snapshot_bytes();
    // metric tag (offset 16) and payload kind (offset 17) — v2+ layout.
    for (offset, what) in [(16usize, "metric"), (17usize, "payload kind")] {
        let mut body = bytes[..bytes.len() - 8].to_vec();
        body[offset] = 7;
        let bad = reseal(body);
        check_snapshot_bytes(&bad);
        let err = format!("{:#}", decode(&bad).unwrap_err());
        assert!(
            err.contains("unknown"),
            "{what} tag 7 should be an 'unknown' error, got: {err}"
        );
    }
}

#[test]
fn regression_huge_shape_overflow_is_caught_before_allocation() {
    // Header-only frame claiming n = d = 2^62: the checked_mul shape
    // guard must reject it cleanly instead of wrapping (or trying to
    // allocate exabytes).
    let mut body = Vec::new();
    body.extend_from_slice(&MAGIC);
    body.extend_from_slice(&3u32.to_le_bytes()); // version
    body.extend_from_slice(&3u32.to_le_bytes()); // k
    body.push(0); // metric: sq-euclidean
    body.push(0); // kind: dense
    for v in [1u64 << 62, 1u64 << 62, 0, 3, 1] {
        body.extend_from_slice(&v.to_le_bytes());
    }
    body.extend_from_slice(&0u64.to_le_bytes()); // ledger seq
    body.extend_from_slice(&3u64.to_le_bytes()); // ledger len
    let bad = reseal(body);
    check_snapshot_bytes(&bad);
    let err = format!("{:#}", decode(&bad).unwrap_err());
    assert!(err.contains("overflow"), "expected an overflow error, got: {err}");
}

#[test]
fn regression_ledger_sum_mismatch_is_rejected() {
    let bytes = real_snapshot_bytes();
    // The tests count lives at header offset 42 (v2+: magic 8 + version
    // 4 + k 4 + metric 1 + kind 1 + n 8 + d 8 + fingerprint 8). Bumping
    // it breaks the ledger-sum agreement AND (for dense payloads whose
    // size doesn't depend on t, like this one) leaves the body-size
    // equation intact — so this exercises the ledger check, not the
    // size check.
    let mut body = bytes[..bytes.len() - 8].to_vec();
    let mut tests = [0u8; 8];
    tests.copy_from_slice(&body[42..50]);
    let bumped = u64::from_le_bytes(tests) + 7;
    body[42..50].copy_from_slice(&bumped.to_le_bytes());
    let bad = reseal(body);
    check_snapshot_bytes(&bad);
    assert!(decode(&bad).is_err(), "inflated tests count must not decode");
}

#[test]
fn regression_rejected_protocol_frames_leave_session_identical() {
    // The property the protocol fuzz target enforces, pinned on the
    // frames most likely to regress: failures that occur after argument
    // parsing has already begun.
    for frame in [
        r#"{"cmd":"ingest","x":[0.5,1.0,2.0],"y":[0,1]}"#,
        r#"{"cmd":"ingest","x":[1e400,0.0],"y":[1]}"#,
        r#"{"cmd":"add_train","x":[0.5],"y":1}"#,
        r#"{"cmd":"remove_train","i":12345}"#,
        r#"{"cmd":"relabel","i":12345,"y":0}"#,
        r#"{"cmd":"topk","k":2,"by":"sideways"}"#,
    ] {
        check_protocol_line(frame.as_bytes());
    }
}

#[test]
fn baseline_session_is_deterministic() {
    // Crasher reproducibility depends on the fuzz baseline being
    // bit-stable across runs (and across the fuzzer/test boundary).
    let a = baseline_session();
    let b = baseline_session();
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(a.tests_seen(), b.tests_seen());
    assert_eq!(a.raw_point_sums().0, b.raw_point_sums().0);
}
