//! Observability must be free of observable effect (DESIGN.md §14).
//!
//! The zero-overhead contract has two halves, and this file proves the
//! half that matters for correctness: attaching a metrics registry to
//! any layer NEVER changes a computed result. Identical deterministic
//! traffic is driven through a pair of identically-seeded instances —
//! one with obs disabled, one enabled (and, for the server, with the
//! slow-query log firing on every command) — and every piece of engine
//! state is compared TO THE BIT: train labels, point values, dense
//! matrix cells, mutable pair cells, and the serialized protocol
//! responses themselves. Property-style: the comparison runs across
//! engine configs (dense / implicit / mutable) × seeds.
//!
//! The sharded fan-out path has the same on/off comparison next to its
//! fixture in `stiknn-session/src/shard.rs`; the timer/registry
//! micro-semantics live in `stiknn-core/src/obs/mod.rs`.

use std::sync::Arc;

use stiknn::data::load_dataset;
use stiknn::obs::ObsHandle;
use stiknn::server::{Connection, RegistryConfig, SessionRegistry, TrainData};
use stiknn::session::{Engine, SessionConfig, TopBy, ValuationSession};
use stiknn::util::json::Json;
use stiknn::util::rng::Rng;

const K: usize = 3;

fn train_data() -> TrainData {
    let ds = load_dataset("circle", 24, 6, 11).unwrap();
    TrainData::from_dataset(&ds)
}

fn configs() -> Vec<(&'static str, SessionConfig)> {
    vec![
        ("dense", SessionConfig::new(K)),
        ("implicit", SessionConfig::new(K).with_engine(Engine::Implicit)),
        (
            "mutable",
            SessionConfig::new(K)
                .with_engine(Engine::Implicit)
                .with_retained_rows(true)
                .with_mutable(true),
        ),
    ]
}

/// Deterministic mixed traffic: ingest batches, and for mutable
/// sessions the full edit vocabulary. Driven twice from the same seed,
/// it takes the exact same branch at every step on both instances (the
/// states are identical by induction), so tolerated failures fail on
/// both or neither.
fn drive_session(session: &mut ValuationSession, seed: u64, mutable: bool) {
    let mut rng = Rng::new(seed);
    for step in 0..16 {
        let op = if mutable { step % 4 } else { 0 };
        match op {
            1 => {
                let x = [rng.f32() - 0.5, rng.f32() - 0.5];
                let y = rng.below(2) as i32;
                session.add_train(&x, y).unwrap();
            }
            2 => {
                let i = rng.below(session.n());
                let y = rng.below(2) as i32;
                session.relabel_train(i, y).unwrap();
            }
            3 => {
                // may legitimately fail near the k floor — identically
                // on both instances
                let i = rng.below(session.n() + 1);
                let _ = session.remove_train(i);
            }
            _ => {
                let xs = [
                    rng.f32() - 0.5,
                    rng.f32() - 0.5,
                    rng.f32() - 0.5,
                    rng.f32() - 0.5,
                ];
                let ys = [rng.below(2) as i32, rng.below(2) as i32];
                session.ingest(&xs, &ys).unwrap();
            }
        }
    }
}

fn assert_sessions_bit_identical(name: &str, seed: u64, off: &ValuationSession, on: &ValuationSession) {
    assert_eq!(off.n(), on.n(), "{name}/{seed}: train size");
    assert_eq!(off.tests_seen(), on.tests_seen(), "{name}/{seed}: test count");
    assert_eq!(off.revision(), on.revision(), "{name}/{seed}: revision");
    assert_eq!(off.train_labels(), on.train_labels(), "{name}/{seed}: labels");
    for by in [TopBy::Main, TopBy::RowSum] {
        let a = off.point_values(by).unwrap();
        let b = on.point_values(by).unwrap();
        for i in 0..a.len() {
            assert_eq!(
                a[i].to_bits(),
                b[i].to_bits(),
                "{name}/{seed}: {by:?}[{i}] diverged with obs on: {} vs {}",
                a[i],
                b[i]
            );
        }
    }
    if let (Some(a), Some(b)) = (off.matrix(), on.matrix()) {
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{name}/{seed}: matrix cell");
        }
    }
    if let (Some(a), Some(b)) = (off.cell(0, 1), on.cell(0, 1)) {
        assert_eq!(a.to_bits(), b.to_bits(), "{name}/{seed}: cell(0,1)");
    }
}

#[test]
fn session_results_are_bit_identical_with_metrics_on_and_off() {
    let td = train_data();
    for (name, config) in configs() {
        for seed in [7u64, 1234, 0xDEAD] {
            let mut off =
                ValuationSession::new(td.x.clone(), td.y.clone(), td.d, config).unwrap();
            let mut on =
                ValuationSession::new(td.x.clone(), td.y.clone(), td.d, config).unwrap();
            on.set_obs(ObsHandle::enabled("invariants"));
            let mutable = name == "mutable";
            drive_session(&mut off, seed, mutable);
            drive_session(&mut on, seed, mutable);
            assert_sessions_bit_identical(name, seed, &off, &on);
            // and the enabled side actually measured the work it did
            let reg = on.obs().registry().unwrap();
            assert!(reg.counter("session.ingest_batches").get() > 0, "{name}");
            assert!(reg.histogram("session.ingest_ns").count() > 0, "{name}");
            if mutable {
                assert!(reg.counter("session.edits").get() > 0);
                assert!(reg.histogram("session.edit_ns").count() > 0);
            }
        }
    }
}

/// The protocol command lines for one server run: registry verbs plus
/// mixed reads and writes over two sessions, one of them mutable.
fn server_script() -> Vec<String> {
    let mut rng = Rng::new(0x0B5);
    let mut lines = vec![
        r#"{"cmd":"open","name":"plain"}"#.to_string(),
        r#"{"cmd":"open","name":"edits","mutable":true,"k":3}"#.to_string(),
    ];
    for step in 0..24 {
        let session = if step % 2 == 0 { "plain" } else { "edits" };
        lines.push(format!(r#"{{"cmd":"use","name":"{session}"}}"#));
        let a = (rng.below(64) as f64) * 0.125 - 4.0;
        let b = (rng.below(64) as f64) * 0.125 - 4.0;
        let y = rng.below(2);
        lines.push(match step % 6 {
            0 | 1 => format!(r#"{{"cmd":"ingest","x":[{a},{b}],"y":[{y}]}}"#),
            2 => format!(r#"{{"cmd":"add_train","x":[{a},{b}],"y":{y}}}"#),
            3 => r#"{"cmd":"stats"}"#.to_string(),
            4 => r#"{"cmd":"topk","k":5,"by":"rowsum"}"#.to_string(),
            _ => r#"{"cmd":"values"}"#.to_string(),
        });
    }
    lines.push(r#"{"cmd":"list"}"#.to_string());
    lines
}

#[test]
fn server_responses_are_bit_identical_with_metrics_on_and_off() {
    // `add_train` lines hit the dense "plain" session too and fail there
    // (not mutable) — identically on both runs; serialized responses
    // carry every served float, so string equality IS bit equality.
    let run = |obs: bool| -> (Arc<SessionRegistry>, Vec<String>) {
        let mut reg = SessionRegistry::new(
            train_data(),
            RegistryConfig {
                base: SessionConfig::new(K),
                max_resident: 0,
                state_dir: None,
            },
        )
        .unwrap();
        if obs {
            // slow_ms = 0 logs EVERY command: the slow-query path itself
            // is part of what must not perturb results
            reg = reg
                .with_obs(ObsHandle::enabled("invariants"))
                .with_slow_ms(Some(0));
        }
        let reg = Arc::new(reg);
        let mut conn = Connection::new(Arc::clone(&reg), None);
        let responses = server_script()
            .iter()
            .map(|line| {
                let (r, shutdown) = conn.execute(line);
                assert!(!shutdown);
                r.to_string()
            })
            .collect();
        (reg, responses)
    };
    let (_off_reg, off) = run(false);
    let (on_reg, on) = run(true);
    assert_eq!(off.len(), on.len());
    for (i, (a, b)) in off.iter().zip(&on).enumerate() {
        assert_eq!(a, b, "response {i} diverged with obs on");
    }
    // the enabled run measured every command, and logged each as slow
    let total = server_script().len() as u64;
    let reg = on_reg.obs().registry().unwrap();
    assert_eq!(reg.counter("server.commands").get(), total);
    assert_eq!(reg.counter("server.slow_queries").get(), total);
    assert!(reg.histogram("registry.lock_hold_ns").count() > 0);
}
