//! Observability must be free of observable effect (DESIGN.md §14).
//!
//! The zero-overhead contract has two halves, and this file proves the
//! half that matters for correctness: attaching a metrics registry to
//! any layer NEVER changes a computed result. Identical deterministic
//! traffic is driven through a pair of identically-seeded instances —
//! one with obs disabled, one enabled (and, for the server, with the
//! slow-query log firing on every command) — and every piece of engine
//! state is compared TO THE BIT: train labels, point values, dense
//! matrix cells, mutable pair cells, and the serialized protocol
//! responses themselves. Property-style: the comparison runs across
//! engine configs (dense / implicit / mutable) × seeds.
//!
//! Request tracing (DESIGN.md §16) extends the same contract: the
//! trace-off / trace-on / sampled comparisons below prove the span
//! store never changes a result either, and the TCP fan-out test
//! asserts the ISSUE 9 acceptance tree — one traced sharded `values`
//! stitches every member's echoed spans into one tree under the
//! coordinator's root.
//!
//! The sharded fan-out path has the same on/off comparison next to its
//! fixture in `stiknn-session/src/shard.rs`; the timer/registry
//! micro-semantics live in `stiknn-core/src/obs/mod.rs`.

use std::sync::Arc;

use stiknn::coordinator::shard::{ShardPlan, ShardedSession, TcpLink};
use stiknn::data::load_dataset;
use stiknn::obs::{ObsHandle, TraceHandle, TraceMode};
use stiknn::server::{self, Connection, RegistryConfig, SessionRegistry, ShardIdentity, TrainData};
use stiknn::session::{Engine, SessionConfig, TopBy, ValuationSession};
use stiknn::util::json::Json;
use stiknn::util::rng::Rng;

const K: usize = 3;

fn train_data() -> TrainData {
    let ds = load_dataset("circle", 24, 6, 11).unwrap();
    TrainData::from_dataset(&ds)
}

fn configs() -> Vec<(&'static str, SessionConfig)> {
    vec![
        ("dense", SessionConfig::new(K)),
        ("implicit", SessionConfig::new(K).with_engine(Engine::Implicit)),
        (
            "mutable",
            SessionConfig::new(K)
                .with_engine(Engine::Implicit)
                .with_retained_rows(true)
                .with_mutable(true),
        ),
    ]
}

/// Deterministic mixed traffic: ingest batches, and for mutable
/// sessions the full edit vocabulary. Driven twice from the same seed,
/// it takes the exact same branch at every step on both instances (the
/// states are identical by induction), so tolerated failures fail on
/// both or neither.
fn drive_session(session: &mut ValuationSession, seed: u64, mutable: bool) {
    let mut rng = Rng::new(seed);
    for step in 0..16 {
        let op = if mutable { step % 4 } else { 0 };
        match op {
            1 => {
                let x = [rng.f32() - 0.5, rng.f32() - 0.5];
                let y = rng.below(2) as i32;
                session.add_train(&x, y).unwrap();
            }
            2 => {
                let i = rng.below(session.n());
                let y = rng.below(2) as i32;
                session.relabel_train(i, y).unwrap();
            }
            3 => {
                // may legitimately fail near the k floor — identically
                // on both instances
                let i = rng.below(session.n() + 1);
                let _ = session.remove_train(i);
            }
            _ => {
                let xs = [
                    rng.f32() - 0.5,
                    rng.f32() - 0.5,
                    rng.f32() - 0.5,
                    rng.f32() - 0.5,
                ];
                let ys = [rng.below(2) as i32, rng.below(2) as i32];
                session.ingest(&xs, &ys).unwrap();
            }
        }
    }
}

fn assert_sessions_bit_identical(name: &str, seed: u64, off: &ValuationSession, on: &ValuationSession) {
    assert_eq!(off.n(), on.n(), "{name}/{seed}: train size");
    assert_eq!(off.tests_seen(), on.tests_seen(), "{name}/{seed}: test count");
    assert_eq!(off.revision(), on.revision(), "{name}/{seed}: revision");
    assert_eq!(off.train_labels(), on.train_labels(), "{name}/{seed}: labels");
    for by in [TopBy::Main, TopBy::RowSum] {
        let a = off.point_values(by).unwrap();
        let b = on.point_values(by).unwrap();
        for i in 0..a.len() {
            assert_eq!(
                a[i].to_bits(),
                b[i].to_bits(),
                "{name}/{seed}: {by:?}[{i}] diverged with obs on: {} vs {}",
                a[i],
                b[i]
            );
        }
    }
    if let (Some(a), Some(b)) = (off.matrix(), on.matrix()) {
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{name}/{seed}: matrix cell");
        }
    }
    if let (Some(a), Some(b)) = (off.cell(0, 1), on.cell(0, 1)) {
        assert_eq!(a.to_bits(), b.to_bits(), "{name}/{seed}: cell(0,1)");
    }
}

#[test]
fn session_results_are_bit_identical_with_metrics_on_and_off() {
    let td = train_data();
    for (name, config) in configs() {
        for seed in [7u64, 1234, 0xDEAD] {
            let mut off =
                ValuationSession::new(td.x.clone(), td.y.clone(), td.d, config).unwrap();
            let mut on =
                ValuationSession::new(td.x.clone(), td.y.clone(), td.d, config).unwrap();
            on.set_obs(ObsHandle::enabled("invariants"));
            let mutable = name == "mutable";
            drive_session(&mut off, seed, mutable);
            drive_session(&mut on, seed, mutable);
            assert_sessions_bit_identical(name, seed, &off, &on);
            // and the enabled side actually measured the work it did
            let reg = on.obs().registry().unwrap();
            assert!(reg.counter("session.ingest_batches").get() > 0, "{name}");
            assert!(reg.histogram("session.ingest_ns").count() > 0, "{name}");
            if mutable {
                assert!(reg.counter("session.edits").get() > 0);
                assert!(reg.histogram("session.edit_ns").count() > 0);
            }
        }
    }
}

/// The protocol command lines for one server run: registry verbs plus
/// mixed reads and writes over two sessions, one of them mutable.
fn server_script() -> Vec<String> {
    let mut rng = Rng::new(0x0B5);
    let mut lines = vec![
        r#"{"cmd":"open","name":"plain"}"#.to_string(),
        r#"{"cmd":"open","name":"edits","mutable":true,"k":3}"#.to_string(),
    ];
    for step in 0..24 {
        let session = if step % 2 == 0 { "plain" } else { "edits" };
        lines.push(format!(r#"{{"cmd":"use","name":"{session}"}}"#));
        let a = (rng.below(64) as f64) * 0.125 - 4.0;
        let b = (rng.below(64) as f64) * 0.125 - 4.0;
        let y = rng.below(2);
        lines.push(match step % 6 {
            0 | 1 => format!(r#"{{"cmd":"ingest","x":[{a},{b}],"y":[{y}]}}"#),
            2 => format!(r#"{{"cmd":"add_train","x":[{a},{b}],"y":{y}}}"#),
            3 => r#"{"cmd":"stats"}"#.to_string(),
            4 => r#"{"cmd":"topk","k":5,"by":"rowsum"}"#.to_string(),
            _ => r#"{"cmd":"values"}"#.to_string(),
        });
    }
    lines.push(r#"{"cmd":"list"}"#.to_string());
    lines
}

#[test]
fn server_responses_are_bit_identical_with_metrics_on_and_off() {
    // `add_train` lines hit the dense "plain" session too and fail there
    // (not mutable) — identically on both runs; serialized responses
    // carry every served float, so string equality IS bit equality.
    let run = |obs: bool| -> (Arc<SessionRegistry>, Vec<String>) {
        let mut reg = SessionRegistry::new(
            train_data(),
            RegistryConfig {
                base: SessionConfig::new(K),
                max_resident: 0,
                state_dir: None,
            },
        )
        .unwrap();
        if obs {
            // slow_ms = 0 logs EVERY command: the slow-query path itself
            // is part of what must not perturb results
            reg = reg
                .with_obs(ObsHandle::enabled("invariants"))
                .with_slow_ms(Some(0));
        }
        let reg = Arc::new(reg);
        let mut conn = Connection::new(Arc::clone(&reg), None);
        let responses = server_script()
            .iter()
            .map(|line| {
                let (r, shutdown) = conn.execute(line);
                assert!(!shutdown);
                r.to_string()
            })
            .collect();
        (reg, responses)
    };
    let (_off_reg, off) = run(false);
    let (on_reg, on) = run(true);
    assert_eq!(off.len(), on.len());
    for (i, (a, b)) in off.iter().zip(&on).enumerate() {
        assert_eq!(a, b, "response {i} diverged with obs on");
    }
    // the enabled run measured every command, and logged each as slow
    let total = server_script().len() as u64;
    let reg = on_reg.obs().registry().unwrap();
    assert_eq!(reg.counter("server.commands").get(), total);
    assert_eq!(reg.counter("server.slow_queries").get(), total);
    assert!(reg.histogram("registry.lock_hold_ns").count() > 0);
}

/// The tracing half of the zero-overhead contract (DESIGN.md §16): a
/// span store attached to a session NEVER changes a computed result, at
/// any sampling rate. Same instance pairing as the metrics test above,
/// across dense / implicit / mutable × seeds × {on, sampled}.
#[test]
fn session_results_are_bit_identical_with_tracing_off_on_and_sampled() {
    let td = train_data();
    for (name, config) in configs() {
        let mutable = name == "mutable";
        for seed in [3u64, 0xBEEF] {
            let mut off =
                ValuationSession::new(td.x.clone(), td.y.clone(), td.d, config).unwrap();
            drive_session(&mut off, seed, mutable);
            for (mode, handle) in [
                ("on", TraceHandle::enabled()),
                ("sampled", TraceHandle::with_mode(TraceMode::Sampled(2))),
            ] {
                let mut on =
                    ValuationSession::new(td.x.clone(), td.y.clone(), td.d, config).unwrap();
                on.set_trace(handle);
                drive_session(&mut on, seed, mutable);
                assert_sessions_bit_identical(&format!("{name}/trace={mode}"), seed, &off, &on);
                // the traced side really recorded spans — with no server
                // scope set, each ingest opens its own root
                assert!(
                    !on.trace().recent_roots(64).is_empty(),
                    "{name}/{mode}: no spans recorded"
                );
            }
        }
    }
}

/// Same contract one layer up: the full server script replayed with
/// tracing off / on / sampled serves byte-identical response lines —
/// span recording must never leak into a response a client didn't ask
/// to carry trace context.
#[test]
fn server_responses_are_bit_identical_with_tracing_off_on_and_sampled() {
    let run = |trace: Option<TraceHandle>| -> (Arc<SessionRegistry>, Vec<String>) {
        let mut reg = SessionRegistry::new(
            train_data(),
            RegistryConfig {
                base: SessionConfig::new(K),
                max_resident: 0,
                state_dir: None,
            },
        )
        .unwrap()
        .with_obs(ObsHandle::enabled("invariants"));
        if let Some(t) = trace {
            reg = reg.with_trace(t);
        }
        let reg = Arc::new(reg);
        let mut conn = Connection::new(Arc::clone(&reg), None);
        let responses = server_script()
            .iter()
            .map(|line| {
                let (r, shutdown) = conn.execute(line);
                assert!(!shutdown);
                r.to_string()
            })
            .collect();
        (reg, responses)
    };
    let (_off_reg, off) = run(None);
    for (mode, handle) in [
        ("on", TraceHandle::enabled()),
        ("sampled", TraceHandle::with_mode(TraceMode::Sampled(3))),
    ] {
        let (reg, on) = run(Some(handle));
        assert_eq!(off.len(), on.len());
        for (i, (a, b)) in off.iter().zip(&on).enumerate() {
            assert_eq!(a, b, "response {i} diverged with trace={mode}");
        }
        // every admitted root is a cmd.* span; sampling admits a strict
        // subset but never zero over a 27-command script at rate 3
        let roots = reg.trace().recent_roots(256);
        assert!(!roots.is_empty(), "trace={mode}: no roots recorded");
        assert!(
            roots.iter().all(|r| r.name.starts_with("cmd.")),
            "trace={mode}: {roots:?}"
        );
        if mode == "sampled" {
            assert!(
                roots.len() < server_script().len(),
                "sampled mode admitted every root"
            );
        }
    }
}

/// One TCP shard member with tracing enabled on its registry (the
/// `serve --trace on --shard-of J/N` configuration).
fn spawn_traced_shard_server(train: TrainData, config: SessionConfig, id: ShardIdentity) -> String {
    let registry = SessionRegistry::new(
        train,
        RegistryConfig {
            base: config,
            max_resident: 0,
            state_dir: None,
        },
    )
    .unwrap()
    .with_shard(id)
    .with_trace(TraceHandle::enabled());
    let registry = Arc::new(registry);
    registry.open("default", None, None).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let _ = server::listen(registry, listener, Some("default".to_string()));
    });
    addr
}

/// The acceptance tree (ISSUE 9): one traced sharded `values` across
/// two real TCP members stitches into ONE tree on the coordinator —
/// exactly one root, a per-shard round-trip span each carrying the
/// member's echoed server span, every span under the root's trace id,
/// and a merge span whose wall clock bounds the measured fold work.
#[test]
fn traced_sharded_values_assembles_one_tree_across_tcp_members() {
    let td = train_data();
    let config = SessionConfig::new(K);
    let addrs: Vec<String> = (0..2)
        .map(|j| {
            spawn_traced_shard_server(td.clone(), config, ShardIdentity::new(j, 2).unwrap())
        })
        .collect();
    let links: Vec<TcpLink> = addrs.iter().map(|a| TcpLink::connect(a).unwrap()).collect();
    let plan = ShardPlan::contiguous(4, 2);
    let mut sharded = ShardedSession::open(links, plan, td.d).unwrap();
    sharded.set_obs(ObsHandle::enabled("shard"));
    sharded.set_trace(TraceHandle::enabled());
    let test_x = [0.1f32, -0.2, 0.3, 0.4, -0.5, 0.6, 0.7, -0.8];
    let test_y = [0i32, 1, 0, 1];
    sharded.ingest(&test_x, &test_y).unwrap();
    sharded.values().unwrap();

    let trace = sharded.trace().clone();
    let root = trace
        .recent_roots(8)
        .into_iter()
        .find(|r| r.name == "shard.values")
        .expect("shard.values root span");
    let spans = trace.spans_of(root.trace_id);
    // exactly one root, and every span belongs to its trace
    let tops: Vec<_> = spans.iter().filter(|s| s.parent_id.is_none()).collect();
    assert_eq!(tops.len(), 1, "{spans:?}");
    assert_eq!(tops[0].span_id, root.span_id);
    assert!(spans.iter().all(|s| s.trace_id == root.trace_id));
    // one echoed member span per shard, each under its round-trip span
    let members: Vec<_> = spans.iter().filter(|s| s.name == "member.values").collect();
    assert_eq!(members.len(), 2, "{spans:?}");
    for m in &members {
        let call = spans
            .iter()
            .find(|s| Some(s.span_id) == m.parent_id)
            .expect("member span's round-trip parent");
        assert!(call.name.starts_with("shard.s"), "{}", call.name);
        assert_eq!(call.parent_id, Some(root.span_id));
    }
    // the merge span wraps the whole fold, so its wall clock bounds the
    // add-only shard.merge_ns accumulation
    let merge = spans
        .iter()
        .find(|s| s.name == "shard.merge")
        .expect("shard.merge span");
    assert_eq!(merge.parent_id, Some(root.span_id));
    let fold_ns = sharded
        .obs()
        .registry()
        .unwrap()
        .histogram("shard.merge_ns")
        .sum_ns();
    assert!(
        merge.dur_ns >= fold_ns,
        "merge span {}ns shorter than fold work {fold_ns}ns",
        merge.dur_ns
    );
}

/// The server's trace surface at the [`Connection`] level: adopted
/// context is echoed as `"spans"` (and only then), the `trace` verb
/// lists recent roots and fetches one trace by id, and a malformed id
/// is a protocol error, not a panic.
#[test]
fn server_trace_verb_lists_roots_and_fetches_by_id() {
    let reg = SessionRegistry::new(
        train_data(),
        RegistryConfig {
            base: SessionConfig::new(K),
            max_resident: 0,
            state_dir: None,
        },
    )
    .unwrap()
    .with_trace(TraceHandle::enabled());
    let reg = Arc::new(reg);
    reg.open("default", None, None).unwrap();
    let mut conn = Connection::new(Arc::clone(&reg), Some("default".to_string()));

    // untraced command: recorded as a root, NO "spans" on the response
    let (r, _) = conn.execute(r#"{"cmd":"ingest","x":[0.1,0.2],"y":[1]}"#);
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r}");
    assert!(r.get("spans").is_none(), "{r}");

    // traced command: the server adopts the caller's ids and echoes
    // every span the command produced (member + session at least)
    let (r, _) = conn.execute(
        r#"{"cmd":"ingest","x":[0.3,0.4],"y":[0],"trace":{"id":"00000000000000ab","parent":"00000000000000ab"}}"#,
    );
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r}");
    let spans = r.get("spans").and_then(Json::as_arr).expect("span echo");
    assert!(
        spans
            .iter()
            .any(|s| s.get("name").and_then(Json::as_str) == Some("member.ingest")),
        "{spans:?}"
    );
    assert!(
        spans
            .iter()
            .any(|s| s.get("name").and_then(Json::as_str) == Some("session.ingest")),
        "{spans:?}"
    );
    assert!(spans
        .iter()
        .all(|s| s.get("trace").and_then(Json::as_str) == Some("00000000000000ab")));

    // the trace verb lists the untraced command's root...
    let (r, _) = conn.execute(r#"{"cmd":"trace"}"#);
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r}");
    let roots = r.get("roots").and_then(Json::as_arr).unwrap();
    let root_id = roots
        .iter()
        .find_map(|s| {
            (s.get("name").and_then(Json::as_str) == Some("cmd.ingest"))
                .then(|| s.get("trace").and_then(Json::as_str).unwrap().to_string())
        })
        .expect("cmd.ingest root listed");
    // ...and fetching that id returns its spans
    let (r, _) = conn.execute(&format!(r#"{{"cmd":"trace","id":"{root_id}"}}"#));
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r}");
    assert!(!r.get("spans").and_then(Json::as_arr).unwrap().is_empty());
    // a malformed id fails as a protocol error
    let (r, _) = conn.execute(r#"{"cmd":"trace","id":"xyz"}"#);
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "{r}");
}
