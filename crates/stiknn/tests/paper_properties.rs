//! Property-based tests of the paper's mathematical claims, driven by the
//! in-repo prop harness (util::prop): Algorithm 1 against brute-force
//! Eq. (3) and every §3.2 structural invariant, over randomized datasets.

use stiknn::knn::distance::{argsort_by_distance, distances, Metric};
use stiknn::shapley::knn_shapley::knn_shapley_one_test_sorted;
use stiknn::shapley::sii::sii_one_test_sorted;
use stiknn::shapley::sti_exact::{
    exact_one_test_sorted, sii_weight, sti_exact_one_test_sorted,
};
use stiknn::shapley::sti_knn::{sti_knn, sti_one_test_sorted, StiParams};
use stiknn::util::prop::{check, Gen};

/// PROP-1: Algorithm 1 ≡ brute-force Eq. (3), any labels, any k ≤ n.
#[test]
fn prop_sti_knn_equals_bruteforce() {
    check("sti == brute", 60, |g: &mut Gen| {
        let n = g.usize_in(2, 11);
        let k = g.usize_in(1, n);
        let classes = g.usize_in(2, 4);
        let labels = g.labels(n, classes);
        let y = g.rng.below(classes) as i32;
        let fast = sti_one_test_sorted(&labels, y, k);
        let exact = sti_exact_one_test_sorted(&labels, y, k);
        let err = fast.max_abs_diff(&exact);
        assert!(err < 1e-12, "n={n} k={k} labels={labels:?} y={y}: err={err:.2e}");
    });
}

/// PROP-2: same for the SII variant (§3.2's "similar algorithms" claim).
#[test]
fn prop_sii_equals_bruteforce() {
    check("sii == brute", 40, |g: &mut Gen| {
        let n = g.usize_in(2, 10);
        let k = g.usize_in(1, n);
        let labels = g.labels(n, 2);
        let y = g.rng.below(2) as i32;
        let fast = sii_one_test_sorted(&labels, y, k);
        let exact = exact_one_test_sorted(&labels, y, k, sii_weight);
        assert!(
            fast.max_abs_diff(&exact) < 1e-12,
            "n={n} k={k} labels={labels:?} y={y}"
        );
    });
}

/// PROP-3: efficiency — upper triangle incl. diagonal sums to u(N).
#[test]
fn prop_efficiency_axiom() {
    check("efficiency", 80, |g: &mut Gen| {
        let n = g.usize_in(2, 40);
        let k = g.usize_in(1, n);
        let labels = g.labels(n, 3);
        let y = g.rng.below(3) as i32;
        let m = sti_one_test_sorted(&labels, y, k);
        let v_n = labels
            .iter()
            .take(k)
            .filter(|&&l| l == y)
            .count() as f64
            / k as f64;
        assert!(
            (m.upper_triangle_sum() - v_n).abs() < 1e-10,
            "n={n} k={k}: {} vs {v_n}",
            m.upper_triangle_sum()
        );
    });
}

/// PROP-4: column equality (Eq. 8) and symmetry for one test point.
#[test]
fn prop_column_equality_and_symmetry() {
    check("columns", 60, |g: &mut Gen| {
        let n = g.usize_in(3, 30);
        let k = g.usize_in(1, n);
        let labels = g.labels(n, 2);
        let m = sti_one_test_sorted(&labels, 1, k);
        assert!(m.is_symmetric(0.0));
        for j in 1..n {
            for i in 1..j {
                assert_eq!(m.get(i, j), m.get(0, j), "column {j} not constant");
            }
        }
    });
}

/// PROP-5: STI pair values relate to KNN-Shapley per-point values through
/// efficiency — both decompositions sum to the same v(N).
#[test]
fn prop_sti_and_knn_shapley_share_total() {
    check("totals agree", 60, |g: &mut Gen| {
        let n = g.usize_in(2, 35);
        let k = g.usize_in(1, n);
        let labels = g.labels(n, 2);
        let y = g.rng.below(2) as i32;
        let sti = sti_one_test_sorted(&labels, y, k);
        let pts = knn_shapley_one_test_sorted(&labels, y, k);
        assert!(
            (sti.upper_triangle_sum() - pts.iter().sum::<f64>()).abs() < 1e-10,
            "n={n} k={k}"
        );
    });
}

/// PROP-6: metric invariance — STI depends only on distance RANKS, so
/// uniformly scaling all features (a monotone transform of squared
/// euclidean distances) leaves the matrix unchanged.
#[test]
fn prop_scale_invariance() {
    check("rank invariance", 40, |g: &mut Gen| {
        let n = g.usize_in(2, 20);
        let d = g.usize_in(1, 4);
        let k = g.usize_in(1, n);
        let tx = g.features(n, d);
        let ty = g.labels(n, 2);
        let sx = g.features(3, d);
        let sy = g.labels(3, 2);
        let params = StiParams::new(k);
        let a = sti_knn(&tx, &ty, d, &sx, &sy, &params);
        let scaled: Vec<f32> = tx.iter().map(|v| v * 7.5).collect();
        let sscaled: Vec<f32> = sx.iter().map(|v| v * 7.5).collect();
        let b = sti_knn(&scaled, &ty, d, &sscaled, &sy, &params);
        assert!(a.max_abs_diff(&b) < 1e-12, "not scale invariant");
    });
}

/// PROP-7: permutation equivariance — relabeling train indices permutes
/// the matrix accordingly.
#[test]
fn prop_permutation_equivariance() {
    check("permutation equivariance", 30, |g: &mut Gen| {
        let n = g.usize_in(3, 15);
        let d = 2;
        let k = g.usize_in(1, n);
        let tx = g.features(n, d);
        let ty = g.labels(n, 2);
        let sx = g.features(2, d);
        let sy = g.labels(2, 2);
        let perm = g.rng.permutation(n);
        let mut ptx = vec![0.0f32; n * d];
        let mut pty = vec![0i32; n];
        for (new, &old) in perm.iter().enumerate() {
            ptx[new * d..(new + 1) * d].copy_from_slice(&tx[old * d..(old + 1) * d]);
            pty[new] = ty[old];
        }
        let params = StiParams::new(k);
        let base = sti_knn(&tx, &ty, d, &sx, &sy, &params);
        let permuted = sti_knn(&ptx, &pty, d, &sx, &sy, &params);
        // permuted[a][b] should equal base[perm[a]][perm[b]]
        let expected = base.permuted(&perm);
        assert!(
            permuted.max_abs_diff(&expected) < 1e-12,
            "n={n} k={k} perm={perm:?}"
        );
    });
}

/// PROP-8: ties in distance are broken stably (duplicated train points
/// must not corrupt rank computation).
#[test]
fn prop_duplicate_points_stable() {
    check("duplicate stability", 30, |g: &mut Gen| {
        let n = g.usize_in(4, 16);
        let d = 2;
        let mut tx = g.features(n, d);
        // duplicate point 0 onto points 1 and 2
        for c in 1..3 {
            for j in 0..d {
                tx[c * d + j] = tx[j];
            }
        }
        let q = g.features(1, d);
        let dists = distances(&q, &tx, d, Metric::SqEuclidean);
        let order = argsort_by_distance(&dists);
        let r0 = order.iter().position(|&o| o == 0).unwrap();
        let r1 = order.iter().position(|&o| o == 1).unwrap();
        let r2 = order.iter().position(|&o| o == 2).unwrap();
        assert!(r0 < r1 && r1 < r2, "tie-break unstable: {r0} {r1} {r2}");
    });
}

/// PROP-9: Corollary 1 scale effect — multiplying k divides the
/// superdiagonal increments, so max|φ| decreases (weakly) in k for
/// fixed labels.
#[test]
fn prop_scale_shrinks_with_k() {
    check("corollary 1", 40, |g: &mut Gen| {
        let n = g.usize_in(6, 30);
        let labels = g.labels(n, 2);
        let k1 = g.usize_in(1, n / 2);
        let k2 = (k1 * 2).min(n);
        let m1 = sti_one_test_sorted(&labels, 1, k1);
        let m2 = sti_one_test_sorted(&labels, 1, k2);
        let s1: f64 = m1.upper_triangle_entries().iter().map(|v| v.abs()).sum();
        let s2: f64 = m2.upper_triangle_entries().iter().map(|v| v.abs()).sum();
        assert!(
            s2 <= s1 + 1e-12,
            "n={n} k1={k1} k2={k2}: sum|phi| grew {s1} -> {s2}"
        );
    });
}
