//! Coordinator invariants as property tests (DESIGN.md §7):
//! completeness, determinism, backpressure bounds, and failure behaviour.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use stiknn::coordinator::pool::{run_workers, Bounded};
use stiknn::coordinator::{run_job, ValuationJob};
use stiknn::data::load_dataset;
use stiknn::shapley::sti_knn::{sti_knn, StiParams};
use stiknn::util::prop::{check, Gen};

/// INV-1: the pipeline result equals the single-threaded engine for any
/// (workers, block size, dataset shape) combination.
#[test]
fn prop_pipeline_matches_reference() {
    check("pipeline == reference", 12, |g: &mut Gen| {
        let n = g.usize_in(10, 60);
        let t = g.usize_in(1, 40);
        let k = g.usize_in(1, n.min(9));
        let workers = g.usize_in(1, 6);
        let block = g.usize_in(1, 17);
        let ds = load_dataset("cpu", n, t, g.rng.next_u64()).unwrap();
        let reference = sti_knn(
            &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y,
            &StiParams::new(k),
        );
        let job = ValuationJob::new(k).with_workers(workers).with_block_size(block);
        let res = run_job(&ds, &job).unwrap();
        assert_eq!(res.weight, t as f64);
        assert!(
            res.phi.max_abs_diff(&reference) < 1e-12,
            "n={n} t={t} k={k} workers={workers} block={block}"
        );
    });
}

/// INV-2: backpressure — queue occupancy never exceeds capacity, all
/// items processed exactly once, under any producer/consumer ratio.
#[test]
fn prop_bounded_queue_invariants() {
    check("bounded queue", 25, |g: &mut Gen| {
        let capacity = g.usize_in(1, 8);
        let items = g.usize_in(1, 300);
        let consumers = g.usize_in(1, 5);
        let queue = Arc::new(Bounded::new(capacity));
        let processed = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let q = queue.clone();
            s.spawn(move || {
                for i in 0..items {
                    q.send(i).unwrap();
                }
                q.close();
            });
            run_workers(&queue, consumers, |_w, _item: usize| {
                processed.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(processed.load(Ordering::Relaxed), items);
        assert!(
            queue.peak() <= capacity,
            "peak {} > capacity {capacity}",
            queue.peak()
        );
    });
}

/// INV-3: worker crash (panic) does not deadlock the pipeline — the run
/// completes or fails, never hangs. We simulate by closing the queue from
/// a consumer mid-stream and checking producers unblock.
#[test]
fn producer_unblocks_when_queue_closes() {
    let queue: Arc<Bounded<usize>> = Arc::new(Bounded::new(1));
    let produced = Arc::new(Mutex::new(0usize));
    std::thread::scope(|s| {
        let q = queue.clone();
        let p = produced.clone();
        s.spawn(move || {
            for i in 0..1000 {
                if q.send(i).is_err() {
                    break; // producer observed the close — this is the invariant
                }
                *p.lock().unwrap() += 1;
            }
        });
        // consume a couple then close (simulating fail-fast)
        let _ = queue.recv();
        let _ = queue.recv();
        queue.close();
    });
    let sent = *produced.lock().unwrap();
    assert!(sent < 1000, "producer should stop early, sent {sent}");
}

/// INV-4: shard plan covers the test set exactly under arbitrary sizes.
#[test]
fn prop_shard_plan_partition() {
    check("shard partition", 60, |g: &mut Gen| {
        let t = g.usize_in(1, 500);
        let block = g.usize_in(1, 64);
        let job = ValuationJob::new(1).with_block_size(block);
        let shards = job.plan_shards(t);
        let mut covered = vec![false; t];
        for (lo, hi) in shards {
            assert!(lo < hi && hi <= t);
            for c in covered.iter_mut().take(hi).skip(lo) {
                assert!(!*c, "overlap at {lo}..{hi}");
                *c = true;
            }
        }
        assert!(covered.iter().all(|&c| c), "gap in shard plan");
    });
}

/// INV-5: throughput accounting is consistent (points == weight).
#[test]
fn weight_equals_test_points() {
    let ds = load_dataset("moon", 40, 19, 3).unwrap();
    for block in [1usize, 4, 19, 64] {
        let job = ValuationJob::new(3).with_workers(3).with_block_size(block);
        let res = run_job(&ds, &job).unwrap();
        assert_eq!(res.weight, 19.0, "block={block}");
        assert_eq!(res.blocks, 19usize.div_ceil(block));
    }
}
