//! Integration: the AOT XLA artifacts (L1 Pallas + L2 JAX, compiled to
//! HLO text at build time) produce the same numbers as the pure-Rust
//! Algorithm 1 — the cross-language equivalence at the heart of the
//! three-layer architecture.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise —
//! CI always builds artifacts first via the Makefile).

use std::path::PathBuf;

use stiknn::coordinator::{run_job_with_engine, ValuationJob};
use stiknn::data::load_dataset;
use stiknn::runtime::{executor_for, Engine, Manifest};
use stiknn::shapley::knn_shapley::knn_shapley_partial;
use stiknn::shapley::sti_knn::{sti_knn, sti_knn_partial, StiParams};
use stiknn::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built — run `make artifacts`");
        None
    }
}

fn random_problem(n: usize, d: usize, t: usize, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    (
        (0..n * d).map(|_| rng.normal() as f32).collect(),
        (0..n).map(|_| rng.below(2) as i32).collect(),
        (0..t * d).map(|_| rng.normal() as f32).collect(),
        (0..t).map(|_| rng.below(2) as i32).collect(),
    )
}

#[test]
fn sti_artifact_matches_rust_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    // smallest artifact: sti n=32 d=2 b=8 k=3
    let (tx, ty, sx, sy) = random_problem(32, 2, 8, 42);
    let exec = executor_for(&manifest, "sti", 32, 2, 3).unwrap();
    let (phi_xla, w) = exec.run_block(&tx, &ty, &sx, &sy).unwrap();
    assert_eq!(w, 8.0);
    let (phi_rust, _) = sti_knn_partial(&tx, &ty, 2, &sx, &sy, &StiParams::new(3));
    let err = phi_xla.max_abs_diff(&phi_rust);
    assert!(err < 1e-4, "xla vs rust disagreement: {err}");
}

#[test]
fn sti_artifact_partial_block_uses_mask() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    // block of 5 < b=8 exercises padding
    let (tx, ty, sx, sy) = random_problem(32, 2, 5, 7);
    let exec = executor_for(&manifest, "sti", 32, 2, 3).unwrap();
    let (phi_xla, w) = exec.run_block(&tx, &ty, &sx, &sy).unwrap();
    assert_eq!(w, 5.0);
    let (phi_rust, _) = sti_knn_partial(&tx, &ty, 2, &sx, &sy, &StiParams::new(3));
    assert!(phi_xla.max_abs_diff(&phi_rust) < 1e-4);
}

#[test]
fn knn_shapley_artifact_matches_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let (tx, ty, sx, sy) = random_problem(64, 2, 16, 3);
    let exec = executor_for(&manifest, "knn_shapley", 64, 2, 5).unwrap();
    let (s_xla, w) = exec.run_values_block(&tx, &ty, &sx, &sy).unwrap();
    assert_eq!(w, 16.0);
    let (s_rust, _) = knn_shapley_partial(&tx, &ty, 2, &sx, &sy, 5);
    for (a, b) in s_xla.iter().zip(&s_rust) {
        assert!((a - b).abs() < 1e-5, "{s_xla:?} vs {s_rust:?}");
    }
}

#[test]
fn full_pipeline_xla_engine_matches_rust_engine() {
    let Some(dir) = artifacts_dir() else { return };
    // circle @ n=600 d=2 k=5 has a baked artifact
    let ds = load_dataset("circle", 600, 90, 11).unwrap();
    assert_eq!(ds.n_train(), 600);

    let job_rust = ValuationJob::new(5).with_workers(2).with_block_size(32);
    let res_rust = run_job_with_engine(&ds, &job_rust, &dir).unwrap();

    let job_xla = job_rust.clone().with_engine(Engine::Xla).with_workers(2);
    let res_xla = run_job_with_engine(&ds, &job_xla, &dir).unwrap();

    assert_eq!(res_rust.weight, res_xla.weight);
    let err = res_rust.phi.max_abs_diff(&res_xla.phi);
    // f32 artifact accumulates a 600×600 matrix over 32-point blocks
    assert!(err < 5e-4, "engines disagree: {err}");
}

#[test]
fn missing_artifact_error_is_actionable() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let Err(e) = executor_for(&manifest, "sti", 999, 2, 3) else {
        panic!("expected missing-artifact error");
    };
    let err = format!("{e:#}");
    assert!(err.contains("make artifacts"), "unhelpful error: {err}");
    assert!(err.contains("--engine rust"), "unhelpful error: {err}");
}

#[test]
fn xla_engine_respects_efficiency_axiom() {
    let Some(dir) = artifacts_dir() else { return };
    let ds = load_dataset("circle", 600, 40, 5).unwrap();
    let job = ValuationJob::new(5).with_engine(Engine::Xla).with_workers(1);
    let res = run_job_with_engine(&ds, &job, &dir).unwrap();
    let reports = stiknn::shapley::axioms::check_all(
        &res.phi, &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, 5,
        1e-3, // f32 artifact tolerance
    );
    assert!(
        stiknn::shapley::axioms::all_hold(&reports),
        "{}",
        stiknn::shapley::axioms::format_reports(&reports)
    );
}

#[test]
fn rust_reference_on_artifact_shape_for_direct_comparison() {
    // pure-rust path on the same shapes as the artifacts (no artifacts
    // needed): guards against the test above silently skipping everywhere
    let (tx, ty, sx, sy) = random_problem(32, 2, 8, 42);
    let m = sti_knn(&tx, &ty, 2, &sx, &sy, &StiParams::new(3));
    assert!(m.is_symmetric(0.0));
}
