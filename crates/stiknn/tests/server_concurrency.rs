//! Concurrency properties of the multi-session server (DESIGN.md §12).
//!
//! The contract under test: N concurrent clients issuing mixed
//! read/mutate traffic against shared and distinct named sessions leave
//! every session BIT-IDENTICAL to a serialized replay of that session's
//! own write commands — at any client count, and across LRU spill→reload
//! cycles through the v3 snapshot store and autosave checkpoints.
//!
//! The serialization order is recovered from the protocol itself: every
//! successful write response carries `rev`, the session's monotone write
//! revision. The checks assert the collected revs are exactly 1..=W
//! (no lost or duplicated write) and that replaying the write lines in
//! rev order into a fresh single-threaded session reproduces the served
//! state to the bit.

use std::path::PathBuf;
use std::sync::Arc;

use stiknn::data::load_dataset;
use stiknn::server::{Connection, RegistryConfig, SessionRegistry, TrainData};
use stiknn::session::{protocol, Engine, SessionConfig, TopBy, ValuationSession};
use stiknn::util::json::Json;
use stiknn::util::rng::Rng;

const K: usize = 3;

fn train_data() -> TrainData {
    let ds = load_dataset("circle", 24, 6, 11).unwrap();
    TrainData::from_dataset(&ds)
}

fn dense_config() -> SessionConfig {
    SessionConfig::new(K)
}

fn implicit_config() -> SessionConfig {
    SessionConfig::new(K).with_engine(Engine::Implicit)
}

fn mutable_config() -> SessionConfig {
    SessionConfig::new(K)
        .with_engine(Engine::Implicit)
        .with_retained_rows(true)
        .with_mutable(true)
}

fn config_of(name: &str) -> SessionConfig {
    match name {
        "dense" => dense_config(),
        "imp" => implicit_config(),
        "mut" => mutable_config(),
        other => panic!("unknown test session '{other}'"),
    }
}

/// One client's deterministic write line for (session, client, step).
fn write_line(session: &str, client: usize, step: usize) -> String {
    let mut rng = Rng::new(0xC0FFEE + client as u64 * 7919 + step as u64 * 104729);
    let a = (rng.below(64) as f64) * 0.125 - 4.0;
    let b = (rng.below(64) as f64) * 0.125 - 4.0;
    let y = rng.below(2);
    if session == "mut" {
        match step % 4 {
            1 => return format!(r#"{{"cmd":"add_train","x":[{a},{b}],"y":{y}}}"#),
            2 => {
                let i = rng.below(24);
                return format!(r#"{{"cmd":"relabel","i":{i},"y":{y}}}"#);
            }
            3 => {
                // may fail when the index raced out of range — failures
                // don't mutate and are excluded from the replay
                let i = rng.below(26);
                return format!(r#"{{"cmd":"remove_train","i":{i}}}"#);
            }
            _ => {}
        }
    }
    format!(r#"{{"cmd":"ingest","x":[{a},{b}],"y":[{y}]}}"#)
}

fn read_line(session: &str, step: usize) -> String {
    match step % 4 {
        0 => r#"{"cmd":"stats"}"#.to_string(),
        1 => r#"{"cmd":"topk","k":5,"by":"rowsum"}"#.to_string(),
        2 => r#"{"cmd":"values"}"#.to_string(),
        // implicit without retained rows cannot answer off-diagonal
        // cells — use the always-answerable diagonal there
        _ if session == "imp" => r#"{"cmd":"query","i":1,"j":1}"#.to_string(),
        _ => r#"{"cmd":"query","i":0,"j":1}"#.to_string(),
    }
}

/// Drive `clients` worker threads of mixed traffic against `sessions`,
/// returning every successful write as (session, rev, line).
fn run_traffic(
    registry: &Arc<SessionRegistry>,
    sessions: &[&str],
    clients: usize,
    steps: usize,
) -> Vec<(String, u64, String)> {
    let mut writes = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for client in 0..clients {
            let registry = Arc::clone(registry);
            handles.push(scope.spawn(move || {
                let mut conn = Connection::new(registry, None);
                let mut local = Vec::new();
                for step in 0..steps {
                    let session = sessions[(client + step) % sessions.len()];
                    let (r, _) =
                        conn.execute(&format!(r#"{{"cmd":"use","name":"{session}"}}"#));
                    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
                    // 1 write per 3 commands, reads in between
                    let is_write = step % 3 == 0;
                    let line = if is_write {
                        write_line(session, client, step)
                    } else {
                        read_line(session, step)
                    };
                    let (r, shutdown) = conn.execute(&line);
                    assert!(!shutdown);
                    let ok = r.get("ok").unwrap().as_bool().unwrap();
                    if let Some(rev) = r.get("rev").and_then(Json::as_usize) {
                        assert!(ok, "a failed command must not report a rev: {r}");
                        local.push((session.to_string(), rev as u64, line));
                    } else if !ok && is_write {
                        // the only tolerated write failure: an edit whose
                        // index raced out of range (it mutated nothing).
                        // Reads may also fail early (empty session) —
                        // that's the protocol contract, not a concurrency
                        // defect, so they aren't asserted on.
                        let msg = r.get("error").unwrap().as_str().unwrap();
                        assert!(
                            msg.contains("out of range") || msg.contains("cannot remove"),
                            "unexpected write failure: {r}"
                        );
                    }
                }
                local
            }));
        }
        for h in handles {
            writes.extend(h.join().expect("client thread panicked"));
        }
    });
    writes
}

/// Replay a session's writes in rev order into a fresh session and
/// assert the served state matches to the bit.
fn assert_replay_matches(
    registry: &Arc<SessionRegistry>,
    name: &str,
    writes: &[(String, u64, String)],
) {
    let mut own: Vec<(u64, &str)> = writes
        .iter()
        .filter(|(s, _, _)| s == name)
        .map(|(_, rev, line)| (*rev, line.as_str()))
        .collect();
    own.sort_by_key(|&(rev, _)| rev);
    // serialization completeness: revisions are exactly 1..=W
    for (i, &(rev, _)) in own.iter().enumerate() {
        assert_eq!(rev, i as u64 + 1, "lost or duplicated write in '{name}'");
    }
    let td = train_data();
    let mut fresh =
        ValuationSession::new(td.x.clone(), td.y.clone(), td.d, config_of(name)).unwrap();
    for &(_, line) in &own {
        let (r, _) = protocol::handle(&mut fresh, line);
        assert_eq!(
            r.get("ok").unwrap().as_bool(),
            Some(true),
            "replayed write failed in '{name}': {r} for {line}"
        );
    }
    let (n, tests, rev, labels) = registry
        .with_session_read(name, |s| {
            (
                s.n(),
                s.tests_seen(),
                s.revision(),
                s.train_labels().to_vec(),
            )
        })
        .unwrap();
    assert_eq!(rev, own.len() as u64, "'{name}' revision");
    assert_eq!(n, fresh.n(), "'{name}' train size");
    assert_eq!(tests, fresh.tests_seen(), "'{name}' test count");
    assert_eq!(labels, fresh.train_labels(), "'{name}' labels");
    if tests > 0 {
        for by in [TopBy::Main, TopBy::RowSum] {
            let served = registry
                .with_session_read(name, |s| s.point_values(by).unwrap())
                .unwrap();
            let replayed = fresh.point_values(by).unwrap();
            for i in 0..n {
                assert_eq!(
                    served[i].to_bits(),
                    replayed[i].to_bits(),
                    "'{name}' {by:?}[{i}]: served {} vs replayed {}",
                    served[i],
                    replayed[i]
                );
            }
        }
    }
    // engine-specific pair-level state
    if name == "dense" && tests > 0 {
        let served = registry
            .with_session_read(name, |s| s.matrix().unwrap())
            .unwrap();
        let replayed = fresh.matrix().unwrap();
        for (a, b) in served.data().iter().zip(replayed.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "'dense' matrix cell");
        }
    }
    if name == "mut" && tests > 0 {
        let served = registry
            .with_session_read(name, |s| s.cell(0, 1).unwrap())
            .unwrap();
        assert_eq!(served.to_bits(), fresh.cell(0, 1).unwrap().to_bits());
    }
}

fn fresh_registry(config: RegistryConfig) -> Arc<SessionRegistry> {
    let registry = Arc::new(SessionRegistry::new(train_data(), config).unwrap());
    for name in ["dense", "imp", "mut"] {
        assert!(registry.open(name, None, Some(config_of(name))).unwrap());
    }
    registry
}

fn state_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("stiknn_server_{}_{tag}", std::process::id()))
}

#[test]
fn concurrent_mixed_traffic_equals_serialized_replay() {
    for clients in [2usize, 5] {
        let registry = fresh_registry(RegistryConfig {
            base: dense_config(),
            max_resident: 0,
            state_dir: None,
        });
        let writes = run_traffic(&registry, &["dense", "imp", "mut"], clients, 30);
        assert!(!writes.is_empty());
        for name in ["dense", "imp", "mut"] {
            assert_replay_matches(&registry, name, &writes);
        }
    }
}

#[test]
fn lru_spill_reload_roundtrips_mid_traffic() {
    let dir = state_dir("lru");
    let _ = std::fs::remove_dir_all(&dir);
    let registry = fresh_registry(RegistryConfig {
        base: dense_config(),
        max_resident: 1,
        state_dir: Some(dir.clone()),
    });
    // round-robin traffic over 3 sessions with a single resident slot:
    // every session switch forces a spill of one and a reload of another
    let writes = run_traffic(&registry, &["dense", "imp", "mut"], 3, 24);
    // spills actually happened (snapshots exist for evicted sessions) …
    let spilled = std::fs::read_dir(&dir).unwrap().count();
    assert!(spilled >= 2, "expected spill snapshots, found {spilled}");
    // … and the cap holds once traffic quiesces: eviction skips victims
    // that are busy with in-flight commands, so enforcement completes on
    // the next (now uncontended) touch
    registry.with_session_read("dense", |_| ()).unwrap();
    let resident = registry.list().iter().filter(|i| i.resident).count();
    assert!(resident <= 1, "cap violated: {resident} resident");
    // … and every session still equals its serialized replay, i.e. the
    // spill→reload cycles were bit-transparent (incl. the v3 mutable
    // payload carrying edited train set + rows + mutation ledger)
    for name in ["dense", "imp", "mut"] {
        assert_replay_matches(&registry, name, &writes);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unspillable_sessions_are_pinned_resident() {
    let dir = state_dir("pinned");
    let _ = std::fs::remove_dir_all(&dir);
    let registry = Arc::new(
        SessionRegistry::new(
            train_data(),
            RegistryConfig {
                base: dense_config(),
                max_resident: 1,
                state_dir: Some(dir.clone()),
            },
        )
        .unwrap(),
    );
    // an immutable retained-rows session cannot round-trip a snapshot
    // (rows are not persisted) — it must never be chosen for eviction
    let rows_config = implicit_config().with_retained_rows(true);
    registry.open("rows", None, Some(rows_config)).unwrap();
    let mut conn = Connection::new(Arc::clone(&registry), Some("rows".to_string()));
    let (r, _) = conn.execute(r#"{"cmd":"ingest","x":[0.5,0.5],"y":[1]}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    registry.open("other", None, Some(dense_config())).unwrap();
    let infos = registry.list();
    for i in &infos {
        assert!(i.resident, "'{}' should be resident (cap over-run)", i.name);
    }
    // the retained rows still answer pair queries — nothing was dropped
    let (r, _) = conn.execute(r#"{"cmd":"query","i":0,"j":1}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn autosave_checkpoints_dirty_sessions_and_snapshots_restore() {
    let dir = state_dir("autosave");
    let _ = std::fs::remove_dir_all(&dir);
    let registry = Arc::new(
        SessionRegistry::new(
            train_data(),
            RegistryConfig {
                base: dense_config(),
                max_resident: 0,
                state_dir: Some(dir.clone()),
            },
        )
        .unwrap(),
    );
    registry.open("a", None, None).unwrap();
    let mut conn = Connection::new(Arc::clone(&registry), Some("a".to_string()));
    for _ in 0..2 {
        let (r, _) = conn.execute(r#"{"cmd":"ingest","x":[0.25,-0.5],"y":[0]}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    }
    assert!(registry.list()[0].dirty);
    // direct checkpoint: writes exactly the dirty session, clears dirty
    assert_eq!(registry.checkpoint_dirty().unwrap(), 1);
    assert!(!registry.list()[0].dirty);
    assert_eq!(registry.checkpoint_dirty().unwrap(), 0, "clean = no rewrite");
    let snap = stiknn::session::store::spill_path(&dir, "a");
    assert!(snap.exists());
    // simulated restart: a new registry opens the checkpoint and resumes
    let reborn = Arc::new(
        SessionRegistry::new(
            train_data(),
            RegistryConfig {
                base: dense_config(),
                max_resident: 0,
                state_dir: Some(dir.clone()),
            },
        )
        .unwrap(),
    );
    reborn.open("a", Some(snap.as_path()), None).unwrap();
    let (tests, live) = reborn
        .with_session_read("a", |s| (s.tests_seen(), s.cell(0, 1).unwrap()))
        .unwrap();
    assert_eq!(tests, 2);
    let original = registry
        .with_session_read("a", |s| s.cell(0, 1).unwrap())
        .unwrap();
    assert_eq!(live.to_bits(), original.to_bits(), "checkpoint round-trip");

    // the background thread variant: dirty again, wait for the ticker
    let (r, _) = conn.execute(r#"{"cmd":"ingest","x":[0.25,-0.5],"y":[1]}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    assert!(registry.list()[0].dirty);
    let autosave = stiknn::server::start_autosave(
        Arc::clone(&registry),
        std::time::Duration::from_millis(25),
    );
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while registry.list()[0].dirty && std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(!registry.list()[0].dirty, "autosave never checkpointed");
    drop(autosave); // joins the thread promptly
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn command_counters_equal_commands_sent_across_concurrent_clients() {
    use stiknn::obs::ObsHandle;
    let registry = Arc::new(
        SessionRegistry::new(
            train_data(),
            RegistryConfig {
                base: dense_config(),
                max_resident: 0,
                state_dir: None,
            },
        )
        .unwrap()
        .with_obs(ObsHandle::enabled("concurrency")),
    );
    for name in ["dense", "imp", "mut"] {
        assert!(registry.open(name, None, Some(config_of(name))).unwrap());
    }
    let (clients, steps) = (4usize, 18usize);
    let writes = run_traffic(&registry, &["dense", "imp", "mut"], clients, steps);
    assert!(!writes.is_empty());
    // run_traffic sends exactly 2 commands per step per client (a `use`
    // plus one read/write line): the relaxed counters must lose none of
    // them under concurrency
    let total = (clients * steps * 2) as u64;
    let reg = registry.obs().registry().unwrap();
    assert_eq!(reg.counter("server.commands").get(), total);
    // the per-command latency histograms partition that same total …
    let snap = registry.obs().snapshot_json();
    let hists = snap.get("histograms").unwrap().as_obj().unwrap();
    let hist_total: u64 = hists
        .iter()
        .filter(|(name, _)| name.starts_with("server.cmd."))
        .map(|(_, h)| h.get("count").unwrap().as_usize().unwrap() as u64)
        .sum();
    assert_eq!(hist_total, total, "histogram counts must partition commands");
    // … with the `use` verb accounting for exactly half of it
    assert_eq!(
        reg.histogram("server.cmd.use_ns").count(),
        (clients * steps) as u64
    );
    // tolerated failures (raced edits, early reads) were counted as
    // errors, never dropped; every `use` succeeds, bounding them
    assert!(reg.counter("server.errors").get() <= (clients * steps) as u64);
}

#[test]
fn connection_verbs_open_use_close_list() {
    let registry = Arc::new(
        SessionRegistry::new(
            train_data(),
            RegistryConfig {
                base: dense_config(),
                max_resident: 0,
                state_dir: None,
            },
        )
        .unwrap(),
    );
    let mut conn = Connection::new(Arc::clone(&registry), None);
    // no session selected → routed commands fail cleanly
    let (r, _) = conn.execute(r#"{"cmd":"stats"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
    assert!(r.get("error").unwrap().as_str().unwrap().contains("no session"));
    // open a fresh session (becomes current), then an existing one
    let (r, _) = conn.execute(r#"{"cmd":"open","name":"a"}"#);
    assert_eq!(r.get("created").unwrap().as_bool(), Some(true), "{r}");
    let (r, _) = conn.execute(r#"{"cmd":"stats"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    let (r, _) = conn.execute(r#"{"cmd":"open","name":"a"}"#);
    assert_eq!(r.get("created").unwrap().as_bool(), Some(false), "attach");
    // overrides: a mutable implicit session accepts edits immediately
    let (r, _) = conn.execute(r#"{"cmd":"open","name":"m","mutable":true,"k":2}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    let (r, _) = conn.execute(r#"{"cmd":"ingest","x":[0.1,0.2],"y":[1]}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    let (r, _) = conn.execute(r#"{"cmd":"add_train","x":[0.3,0.4],"y":0}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    // contradictory overrides are rejected
    let (r, _) = conn.execute(r#"{"cmd":"open","name":"x","mutable":true,"engine":"dense"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
    // list: both sessions, current marked
    let (r, _) = conn.execute(r#"{"cmd":"list"}"#);
    assert_eq!(r.get("current").unwrap().as_str(), Some("m"), "{r}");
    let sessions = r.get("sessions").unwrap().as_arr().unwrap();
    assert_eq!(sessions.len(), 2, "{r}");
    // use: switch back, unknown name is a clean error
    let (r, _) = conn.execute(r#"{"cmd":"use","name":"a"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    let (r, _) = conn.execute(r#"{"cmd":"use","name":"ghost"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
    // invalid names can't become spill filenames
    let (r, _) = conn.execute(r#"{"cmd":"open","name":"../evil"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
    // open on a missing snapshot answers cleanly and keeps serving
    let (r, _) = conn.execute(r#"{"cmd":"open","name":"s","snapshot":"/nonexistent/x.snap"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
    assert!(r.get("error").unwrap().as_str().unwrap().contains("snapshot"));
    // close defaults to the current session and clears it
    let (r, _) = conn.execute(r#"{"cmd":"close"}"#);
    assert_eq!(r.get("name").unwrap().as_str(), Some("a"), "{r}");
    let (r, _) = conn.execute(r#"{"cmd":"stats"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
    // the other session survives; closing an unknown name errors
    let (r, _) = conn.execute(r#"{"cmd":"close","name":"ghost"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
    let (r, _) = conn.execute(r#"{"cmd":"use","name":"m"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
}
