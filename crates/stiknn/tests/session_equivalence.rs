//! Streaming-equivalence properties for the session layer (ISSUE 2 /
//! DESIGN.md §9): ingesting ANY contiguous partition of a test set, in
//! stream order, with a snapshot/restore cycle at an arbitrary point
//! mid-stream, is **bit-identical** to one-shot `sti_knn` — Eq. 9 is
//! additive over test points and no batch boundary can reorder a cell's
//! additions. Re-ordered batches are exact only up to f64 associativity,
//! which is asserted separately (and deliberately NOT bitwise).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use stiknn::session::{SessionConfig, ValuationSession};
use stiknn::shapley::sti_knn::{sti_knn, StiParams};
use stiknn::util::prop::{check, Gen};

static SNAP_COUNTER: AtomicU64 = AtomicU64::new(0);

fn temp_snapshot_path() -> PathBuf {
    let unique = SNAP_COUNTER.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "stiknn_session_equiv_{}_{unique}.snap",
        std::process::id()
    ))
}

struct Problem {
    n: usize,
    d: usize,
    t: usize,
    k: usize,
    train_x: Vec<f32>,
    train_y: Vec<i32>,
    test_x: Vec<f32>,
    test_y: Vec<i32>,
}

fn random_problem(g: &mut Gen) -> Problem {
    let n = 2 + g.usize_in(2, 38);
    let d = 1 + g.usize_in(0, 3);
    let t = 1 + g.usize_in(0, 24);
    let k = 1 + g.usize_in(0, n - 1);
    let classes = 2 + g.usize_in(0, 2);
    Problem {
        n,
        d,
        t,
        k,
        train_x: g.features(n, d),
        train_y: g.labels(n, classes),
        test_x: g.features(t, d),
        test_y: g.labels(t, classes),
    }
}

/// A random contiguous partition of [0, t) into non-empty batches.
fn random_partition(g: &mut Gen, t: usize) -> Vec<(usize, usize)> {
    let mut cuts = vec![0, t];
    for _ in 0..g.usize_in(0, 5) {
        cuts.push(g.usize_in(0, t));
    }
    cuts.sort_unstable();
    cuts.dedup();
    cuts.windows(2).map(|w| (w[0], w[1])).collect()
}

fn assert_bits_equal(a: &stiknn::util::matrix::Matrix, b: &stiknn::util::matrix::Matrix, ctx: &str) {
    assert_eq!(a.data().len(), b.data().len(), "{ctx}: shape");
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: cell {i} diverged ({x:e} vs {y:e})"
        );
    }
}

#[test]
fn any_partition_with_snapshot_restore_is_bit_identical_to_one_shot() {
    check("session streaming equivalence", 30, |g| {
        let p = random_problem(g);
        let reference = sti_knn(
            &p.train_x, &p.train_y, p.d, &p.test_x, &p.test_y, &StiParams::new(p.k),
        );

        let batches = random_partition(g, p.t);
        let snap_after = g.usize_in(0, batches.len() - 1);
        let mut session = ValuationSession::new(
            p.train_x.clone(),
            p.train_y.clone(),
            p.d,
            SessionConfig::new(p.k),
        )
        .unwrap();

        for (bi, &(lo, hi)) in batches.iter().enumerate() {
            session
                .ingest(&p.test_x[lo * p.d..hi * p.d], &p.test_y[lo..hi])
                .unwrap();
            if bi == snap_after {
                // kill the session mid-stream and resurrect it from disk
                let path = temp_snapshot_path();
                session.save(&path).unwrap();
                session = ValuationSession::restore(
                    &path,
                    p.train_x.clone(),
                    p.train_y.clone(),
                    p.d,
                    SessionConfig::new(p.k),
                )
                .unwrap();
                let _ = std::fs::remove_file(&path);
            }
        }

        assert_eq!(session.tests_seen(), p.t as u64);
        assert_eq!(session.ledger().len(), batches.len());
        let live = session.matrix().expect("non-empty session");
        assert_bits_equal(
            &reference,
            &live,
            &format!("partition {batches:?}, snapshot after batch {snap_after}"),
        );
    });
}

#[test]
fn parallel_ingest_path_is_bit_identical_too() {
    // Same property, forcing every batch through the coordinator's
    // banded prep pool (parallel_min = 1) with multiple workers.
    check("session parallel-path equivalence", 10, |g| {
        let p = random_problem(g);
        let reference = sti_knn(
            &p.train_x, &p.train_y, p.d, &p.test_x, &p.test_y, &StiParams::new(p.k),
        );
        let batches = random_partition(g, p.t);
        let workers = 1 + g.usize_in(0, 3);
        let block = 1 + g.usize_in(0, 7);
        let mut session = ValuationSession::new(
            p.train_x.clone(),
            p.train_y.clone(),
            p.d,
            SessionConfig::new(p.k)
                .with_parallel_min(1)
                .with_workers(workers)
                .with_block_size(block),
        )
        .unwrap();
        for &(lo, hi) in &batches {
            session
                .ingest(&p.test_x[lo * p.d..hi * p.d], &p.test_y[lo..hi])
                .unwrap();
        }
        let live = session.matrix().expect("non-empty session");
        assert_bits_equal(
            &reference,
            &live,
            &format!("workers={workers} block={block} partition {batches:?}"),
        );
    });
}

#[test]
fn reordered_batches_agree_to_float_tolerance_not_bits() {
    // Ingesting the same batches in a DIFFERENT order changes per-cell
    // f64 addition order, so the contract is ~1e-12 agreement (Eq. 9 is
    // mathematically order-free; floats are not associative). This test
    // documents that boundary of the bitwise guarantee.
    check("session batch-order tolerance", 15, |g| {
        let p = random_problem(g);
        let batches = random_partition(g, p.t);
        let build = |order: &[usize]| {
            let mut s = ValuationSession::new(
                p.train_x.clone(),
                p.train_y.clone(),
                p.d,
                SessionConfig::new(p.k),
            )
            .unwrap();
            for &bi in order {
                let (lo, hi) = batches[bi];
                s.ingest(&p.test_x[lo * p.d..hi * p.d], &p.test_y[lo..hi])
                    .unwrap();
            }
            s.matrix().unwrap()
        };
        let forward: Vec<usize> = (0..batches.len()).collect();
        let reversed: Vec<usize> = (0..batches.len()).rev().collect();
        let a = build(&forward);
        let b = build(&reversed);
        let diff = a.max_abs_diff(&b);
        assert!(
            diff < 1e-12,
            "reordered ingest diverged beyond tolerance: {diff:e} for {batches:?}"
        );
    });
}
