//! Shard-merge equivalence properties (ISSUE 6 / DESIGN.md §13): a
//! [`ShardedSession`] fanned over N members answers the SAME valuation
//! as one process over the whole test stream.
//!
//! The contract under test, in decreasing strictness:
//!
//! * N = 1: the merge is a copy — every answer is **bit-identical** to
//!   the single-process session (and therefore to one-shot `sti_knn`,
//!   by `tests/session_equivalence.rs`).
//! * N > 1: the cross-shard fold regroups f64 additions, so merged
//!   answers agree to ≤ 1e-12 relative — never worse, at every shard
//!   count, for uneven partitions and zero-test shards alike.
//! * rescatter to M = 1: **bit-identity is recovered** — concatenating
//!   the shards' retained test slices in shard order and re-ingesting
//!   reproduces the single-process session exactly, for ANY source N.
//!
//! The fan-out runs over in-process [`SessionLink`]s (the same
//! `protocol::handle` code path a TCP server executes per line, so the
//! whole command layer is covered) plus one real-TCP test against
//! `server::listen` with `--shard-of`-style registries.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use stiknn::coordinator::shard::{rescatter, SessionLink, ShardPlan, ShardedSession, TcpLink};
use stiknn::server::{self, RegistryConfig, SessionRegistry, ShardIdentity, TrainData};
use stiknn::session::{Engine, SessionConfig, TopBy, ValuationSession};
use stiknn::util::prop::{check, Gen};
use stiknn::util::rng::Rng;

static SNAP_COUNTER: AtomicU64 = AtomicU64::new(0);

fn temp_snapshot_path() -> PathBuf {
    let unique = SNAP_COUNTER.fetch_add(1, Ordering::Relaxed);
    let name = format!("stiknn_shard_equiv_{}_{unique}.snap", std::process::id());
    std::env::temp_dir().join(name)
}

struct Problem {
    n: usize,
    d: usize,
    t: usize,
    k: usize,
    train_x: Vec<f32>,
    train_y: Vec<i32>,
    test_x: Vec<f32>,
    test_y: Vec<i32>,
}

fn random_problem(g: &mut Gen) -> Problem {
    let n = 2 + g.usize_in(2, 30);
    let d = 1 + g.usize_in(0, 3);
    let t = 1 + g.usize_in(0, 20);
    let k = 1 + g.usize_in(0, n - 1);
    let classes = 2 + g.usize_in(0, 2);
    Problem {
        n,
        d,
        t,
        k,
        train_x: g.features(n, d),
        train_y: g.labels(n, classes),
        test_x: g.features(t, d),
        test_y: g.labels(t, classes),
    }
}

fn session(p: &Problem, config: SessionConfig) -> ValuationSession {
    ValuationSession::new(p.train_x.clone(), p.train_y.clone(), p.d, config).unwrap()
}

/// N links over fresh sessions with identical config — `links[s]` is
/// shard s.
fn links(p: &Problem, config: SessionConfig, n_shards: usize) -> Vec<SessionLink> {
    (0..n_shards).map(|_| SessionLink::new(session(p, config))).collect()
}

/// A random contiguous partition of [0, t) into non-empty batches.
fn random_batches(g: &mut Gen, t: usize) -> Vec<(usize, usize)> {
    let mut cuts = vec![0, t];
    for _ in 0..g.usize_in(0, 4) {
        cuts.push(g.usize_in(0, t));
    }
    cuts.sort_unstable();
    cuts.dedup();
    cuts.windows(2).map(|w| (w[0], w[1])).collect()
}

fn ingest_batched(
    sharded: &mut ShardedSession<SessionLink>,
    p: &Problem,
    batches: &[(usize, usize)],
) {
    for &(lo, hi) in batches {
        let (xs, ys) = (&p.test_x[lo * p.d..hi * p.d], &p.test_y[lo..hi]);
        sharded.ingest(xs, ys).unwrap();
    }
}

fn assert_close(a: f64, b: f64, ctx: &str) {
    let scale = a.abs().max(b.abs()).max(1.0);
    assert!((a - b).abs() <= 1e-12 * scale, "{ctx}: {a:e} vs {b:e}");
}

#[test]
fn merged_values_match_the_single_process_session_at_every_shard_count() {
    check("shard merge equivalence", 25, |g| {
        let p = random_problem(g);
        let config = if g.usize_in(0, 1) == 0 {
            SessionConfig::new(p.k)
        } else {
            SessionConfig::new(p.k).with_engine(Engine::Implicit)
        };
        let mut solo = session(&p, config);
        solo.ingest(&p.test_x, &p.test_y).unwrap();
        let solo_main = solo.point_values(TopBy::Main).unwrap();
        let solo_rowsum = solo.point_values(TopBy::RowSum).unwrap();

        for n_shards in [1usize, 2, 3, 7] {
            // t < n_shards leaves trailing shards with zero tests — the
            // merge must absorb them as exact additive identities
            let plan = ShardPlan::contiguous(p.t as u64, n_shards);
            let members = links(&p, config, n_shards);
            let mut sharded = ShardedSession::open(members, plan, p.d).unwrap();
            let batches = random_batches(g, p.t);
            ingest_batched(&mut sharded, &p, &batches);
            assert_eq!(sharded.tests_routed(), p.t as u64);

            let merged = sharded.values().unwrap();
            assert_eq!(merged.tests, p.t as u64);
            for i in 0..p.n {
                if n_shards == 1 {
                    // single member: the fold is a copy — bit-identical
                    let (a, b) = (merged.main[i], solo_main[i]);
                    assert_eq!(a.to_bits(), b.to_bits(), "main[{i}]");
                    let (a, b) = (merged.rowsum[i], solo_rowsum[i]);
                    assert_eq!(a.to_bits(), b.to_bits(), "rowsum[{i}]");
                } else {
                    assert_close(merged.main[i], solo_main[i], "main");
                    assert_close(merged.rowsum[i], solo_rowsum[i], "rowsum");
                }
            }

            // top-k ranks the merged values with the session's semantics
            let k_top = 1 + g.usize_in(0, p.n - 1);
            let top = sharded.top_k(k_top, TopBy::RowSum).unwrap();
            assert_eq!(top.len(), k_top.min(p.n));

            // summary statistics derive from the same merged raw sums
            let solo_stats = solo.stats();
            let merged_stats = sharded.stats().unwrap();
            assert_eq!(merged_stats.tests, solo_stats.tests);
            assert_eq!(merged_stats.per_shard_tests.len(), n_shards);
            let routed: u64 = merged_stats.per_shard_tests.iter().sum();
            assert_eq!(routed, p.t as u64);
            assert_close(merged_stats.trace, solo_stats.trace, "trace");
            assert_close(merged_stats.upper_sum, solo_stats.upper_sum, "upper_sum");
            assert_close(
                merged_stats.mean_offdiag,
                solo_stats.mean_offdiag,
                "mean_offdiag",
            );
        }
    });
}

#[test]
fn single_shard_fan_out_answers_dense_cells_and_rows_bitwise() {
    check("single-shard dense queries", 20, |g| {
        let p = random_problem(g);
        let config = SessionConfig::new(p.k);
        let mut solo = session(&p, config);
        solo.ingest(&p.test_x, &p.test_y).unwrap();

        let plan = ShardPlan::contiguous(p.t as u64, 1);
        let mut sharded = ShardedSession::open(links(&p, config, 1), plan, p.d).unwrap();
        sharded.ingest(&p.test_x, &p.test_y).unwrap();

        let i = g.usize_in(0, p.n - 1);
        let j = g.usize_in(0, p.n - 1);
        assert_eq!(
            sharded.cell(i, j).unwrap().to_bits(),
            solo.cell(i, j).unwrap().to_bits()
        );
        let merged_row = sharded.row(i).unwrap();
        let solo_row = solo.row(i).unwrap();
        for (a, b) in merged_row.iter().zip(&solo_row) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    });
}

#[test]
fn uneven_partitions_and_zero_test_shards_merge_exactly() {
    // Hand-built plan: shard 1 is deliberately EMPTY ([2, 2)) and the
    // split is uneven — routing must skip the empty member and the merge
    // must still match the single process.
    let mut g = Gen {
        rng: Rng::new(0x5AD5),
        size: 24,
    };
    let mut p = random_problem(&mut g);
    p.t = 7;
    p.test_x = g.features(p.t, p.d);
    p.test_y = g.labels(p.t, 2);
    let config = SessionConfig::new(p.k);

    let mut solo = session(&p, config);
    solo.ingest(&p.test_x, &p.test_y).unwrap();

    let plan = ShardPlan::from_starts(vec![0, 2, 2, 6]).unwrap();
    let mut sharded = ShardedSession::open(links(&p, config, 4), plan, p.d).unwrap();
    // one batch that straddles every boundary
    sharded.ingest(&p.test_x, &p.test_y).unwrap();

    let stats = sharded.stats().unwrap();
    assert_eq!(stats.per_shard_tests, vec![2, 0, 4, 1]);

    let merged = sharded.values().unwrap();
    let solo_main = solo.point_values(TopBy::Main).unwrap();
    for i in 0..p.n {
        assert_close(merged.main[i], solo_main[i], "uneven main");
    }
}

#[test]
fn mutations_fan_out_to_every_member() {
    let mut g = Gen {
        rng: Rng::new(0xED17),
        size: 24,
    };
    let p = random_problem(&mut g);
    let config = SessionConfig::new(p.k.min(p.n - 1))
        .with_engine(Engine::Implicit)
        .with_retained_rows(true)
        .with_mutable(true);

    let mut solo = session(&p, config);
    solo.ingest(&p.test_x, &p.test_y).unwrap();

    let plan = ShardPlan::contiguous(p.t as u64, 2);
    let mut sharded = ShardedSession::open(links(&p, config, 2), plan, p.d).unwrap();
    sharded.ingest(&p.test_x, &p.test_y).unwrap();

    // the same edit script on both sides
    let new_x = g.features(1, p.d);
    let added = sharded.add_train(&new_x, 1).unwrap();
    assert_eq!(added, p.n);
    assert_eq!(sharded.n(), p.n + 1);
    solo.add_train(&new_x, 1).unwrap();
    sharded.relabel_train(0, 0).unwrap();
    solo.relabel_train(0, 0).unwrap();
    sharded.remove_train(1).unwrap();
    solo.remove_train(1).unwrap();
    assert_eq!(sharded.n(), p.n);

    let merged = sharded.values().unwrap();
    let solo_main = solo.point_values(TopBy::Main).unwrap();
    for i in 0..sharded.n() {
        assert_close(merged.main[i], solo_main[i], "post-edit main");
    }
}

#[test]
fn rescatter_onto_one_shard_recovers_bit_identity() {
    check("rescatter bit-identity", 15, |g| {
        let p = random_problem(g);
        // mutable members: their snapshots retain the test slices
        let member = SessionConfig::new(p.k)
            .with_engine(Engine::Implicit)
            .with_retained_rows(true)
            .with_mutable(true);
        let n_shards = 1 + g.usize_in(0, 2);
        let plan = ShardPlan::contiguous(p.t as u64, n_shards);
        let members = links(&p, member, n_shards);
        let mut sharded = ShardedSession::open(members, plan, p.d).unwrap();
        let batches = random_batches(g, p.t);
        ingest_batched(&mut sharded, &p, &batches);

        let paths: Vec<PathBuf> = (0..n_shards).map(|_| temp_snapshot_path()).collect();
        let bytes = sharded.snapshot_all(&paths).unwrap();
        assert!(bytes > 0);

        // M = 1, rebuilt DENSE: bitwise vs a fresh single dense session
        // over the same stream, whatever the source shard count was
        let rebuilt = rescatter(&paths, 1, SessionConfig::new(p.k)).unwrap();
        assert_eq!(rebuilt.sessions.len(), 1);
        let mut solo = session(&p, SessionConfig::new(p.k));
        solo.ingest(&p.test_x, &p.test_y).unwrap();
        let a = rebuilt.sessions[0].point_values(TopBy::RowSum).unwrap();
        let b = solo.point_values(TopBy::RowSum).unwrap();
        for i in 0..p.n {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "rescattered rowsum[{i}]");
        }

        // M = 2, rebuilt MUTABLE: resume a coordinator on the rebuilt
        // members and keep serving — merged answers stay within 1e-12
        let rebuilt = rescatter(&paths, 2, member).unwrap();
        let relinked: Vec<SessionLink> =
            rebuilt.sessions.into_iter().map(SessionLink::new).collect();
        let mut resumed = ShardedSession::resume(relinked, rebuilt.plan, p.d).unwrap();
        assert_eq!(resumed.tests_routed(), p.t as u64);
        let merged = resumed.values().unwrap();
        for i in 0..p.n {
            assert_close(merged.rowsum[i], b[i], "resumed rowsum");
        }

        for path in &paths {
            let _ = std::fs::remove_file(path);
        }
    });
}

#[test]
fn rescatter_rejects_immutable_member_snapshots() {
    let mut g = Gen {
        rng: Rng::new(0xA11C),
        size: 24,
    };
    let p = random_problem(&mut g);
    let config = SessionConfig::new(p.k);
    let mut solo = session(&p, config);
    solo.ingest(&p.test_x, &p.test_y).unwrap();
    let path = temp_snapshot_path();
    solo.save(&path).unwrap();
    let err = rescatter(&[&path], 1, config).unwrap_err().to_string();
    assert!(err.contains("does not retain its test slice"), "{err}");
    let _ = std::fs::remove_file(&path);
}

/// One TCP shard server: a registry with a shard identity behind a real
/// listener on a loopback port, accept loop detached (it serves until
/// the test process exits).
fn spawn_shard_server(train: TrainData, config: SessionConfig, id: ShardIdentity) -> String {
    let registry = SessionRegistry::new(
        train,
        RegistryConfig {
            base: config,
            max_resident: 0,
            state_dir: None,
        },
    )
    .unwrap()
    .with_shard(id);
    let registry = Arc::new(registry);
    registry.open("default", None, None).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let _ = server::listen(registry, listener, Some("default".to_string()));
    });
    addr
}

#[test]
fn tcp_shard_servers_merge_like_one_process() {
    let mut g = Gen {
        rng: Rng::new(0x7C9),
        size: 24,
    };
    let p = random_problem(&mut g);
    let config = SessionConfig::new(p.k);
    let train = TrainData {
        name: "shard-equiv".to_string(),
        x: p.train_x.clone(),
        y: p.train_y.clone(),
        d: p.d,
    };

    let addrs: Vec<String> = (0..2)
        .map(|j| spawn_shard_server(train.clone(), config, ShardIdentity::new(j, 2).unwrap()))
        .collect();

    let plan = ShardPlan::contiguous(p.t as u64, 2);
    let links: Vec<TcpLink> = addrs.iter().map(|a| TcpLink::connect(a).unwrap()).collect();
    let mut sharded = ShardedSession::open(links, plan.clone(), p.d).unwrap();
    sharded.ingest(&p.test_x, &p.test_y).unwrap();

    let mut solo = session(&p, config);
    solo.ingest(&p.test_x, &p.test_y).unwrap();
    let merged = sharded.values().unwrap();
    let solo_main = solo.point_values(TopBy::Main).unwrap();
    for i in 0..p.n {
        assert_close(merged.main[i], solo_main[i], "tcp main");
    }

    // the shard verb catches a miswired deployment: connecting the same
    // members in the WRONG order must fail open()
    let swapped: Vec<TcpLink> = addrs
        .iter()
        .rev()
        .map(|a| TcpLink::connect(a).unwrap())
        .collect();
    let err = ShardedSession::open(swapped, plan, p.d).unwrap_err().to_string();
    assert!(err.contains("identifies as shard"), "{err}");
}
