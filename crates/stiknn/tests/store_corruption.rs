//! Snapshot-store corruption coverage (file-level): a truncated file, a
//! flipped checksum byte, and a wrong magic must each produce an
//! actionable error — no panic, and no partially-constructed session.

use std::path::{Path, PathBuf};

use stiknn::session::store::{fnv1a, read_snapshot};
use stiknn::session::{Engine, SessionConfig, ValuationSession};
use stiknn::util::rng::Rng;

fn temp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("stiknn_corrupt_{}_{tag}.snap", std::process::id()))
}

fn problem(seed: u64, n: usize, t: usize) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    (
        (0..n * 2).map(|_| rng.normal() as f32).collect(),
        (0..n).map(|_| rng.below(2) as i32).collect(),
        (0..t * 2).map(|_| rng.normal() as f32).collect(),
        (0..t).map(|_| rng.below(2) as i32).collect(),
    )
}

/// Write one snapshot of each payload kind and return the paths.
fn write_snapshots() -> Vec<(&'static str, PathBuf, Vec<f32>, Vec<i32>)> {
    let mut out = Vec::new();
    // dense
    let (tx, ty, qx, qy) = problem(5, 10, 4);
    let mut dense = ValuationSession::new(tx.clone(), ty.clone(), 2, SessionConfig::new(3)).unwrap();
    dense.ingest(&qx, &qy).unwrap();
    let p = temp("dense");
    dense.save(&p).unwrap();
    out.push(("dense", p, tx, ty));
    // implicit
    let (tx, ty, qx, qy) = problem(7, 10, 4);
    let cfg = SessionConfig::new(3).with_engine(Engine::Implicit);
    let mut imp = ValuationSession::new(tx.clone(), ty.clone(), 2, cfg).unwrap();
    imp.ingest(&qx, &qy).unwrap();
    let p = temp("implicit");
    imp.save(&p).unwrap();
    out.push(("implicit", p, tx, ty));
    // mutable (v3, with edits so the mutation ledger is non-empty)
    let (tx, ty, qx, qy) = problem(9, 10, 4);
    let cfg = SessionConfig::new(3)
        .with_engine(Engine::Implicit)
        .with_retained_rows(true)
        .with_mutable(true);
    let mut m = ValuationSession::new(tx.clone(), ty.clone(), 2, cfg).unwrap();
    m.ingest(&qx, &qy).unwrap();
    m.add_train(&[0.5, -0.5], 1).unwrap();
    m.relabel_train(0, 1).unwrap();
    let p = temp("mutable");
    m.save(&p).unwrap();
    out.push(("mutable", p, tx, ty));
    out
}

fn restore_err(kind: &str, path: &Path, tx: &[f32], ty: &[i32]) -> String {
    if kind == "mutable" {
        let cfg = SessionConfig::new(3)
            .with_engine(Engine::Implicit)
            .with_retained_rows(true)
            .with_mutable(true);
        ValuationSession::restore_mutable(path, cfg)
            .err()
            .map(|e| format!("{e:#}"))
            .unwrap_or_default()
    } else {
        let cfg = if kind == "implicit" {
            SessionConfig::new(3).with_engine(Engine::Implicit)
        } else {
            SessionConfig::new(3)
        };
        ValuationSession::restore(path, tx.to_vec(), ty.to_vec(), 2, cfg)
            .err()
            .map(|e| format!("{e:#}"))
            .unwrap_or_default()
    }
}

#[test]
fn truncated_files_fail_actionably_for_every_payload_kind() {
    for (kind, path, tx, ty) in write_snapshots() {
        let bytes = std::fs::read(&path).unwrap();
        for keep in [bytes.len() - 1, bytes.len() / 2, 30, 5] {
            std::fs::write(&path, &bytes[..keep]).unwrap();
            let err = restore_err(kind, &path, &tx, &ty);
            assert!(
                !err.is_empty(),
                "{kind}: truncation to {keep} bytes must fail"
            );
            assert!(
                err.contains("snapshot") || err.contains("checksum") || err.contains("short"),
                "{kind}/{keep}: unhelpful error: {err}"
            );
        }
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn flipped_bytes_fail_the_checksum_for_every_payload_kind() {
    for (kind, path, tx, ty) in write_snapshots() {
        let bytes = std::fs::read(&path).unwrap();
        // flip a byte in the checksum trailer itself, and one mid-payload
        for flip_at in [bytes.len() - 3, bytes.len() / 2] {
            let mut bad = bytes.clone();
            bad[flip_at] ^= 0x10;
            std::fs::write(&path, &bad).unwrap();
            let err = restore_err(kind, &path, &tx, &ty);
            assert!(
                err.contains("checksum"),
                "{kind}/flip@{flip_at}: expected a checksum error, got: {err}"
            );
        }
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn wrong_magic_fails_actionably_even_with_a_valid_checksum() {
    for (kind, path, tx, ty) in write_snapshots() {
        let bytes = std::fs::read(&path).unwrap();
        // corrupt the magic AND refresh the checksum so the magic check
        // itself (not the checksum) must catch it
        let mut bad = bytes.clone();
        bad[0] = b'Z';
        let body_len = bad.len() - 8;
        let sum = fnv1a(&bad[..body_len]).to_le_bytes();
        bad[body_len..].copy_from_slice(&sum);
        std::fs::write(&path, &bad).unwrap();
        let err = restore_err(kind, &path, &tx, &ty);
        assert!(
            err.contains("magic"),
            "{kind}: expected a magic error, got: {err}"
        );
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn garbage_and_missing_files_fail_without_panicking() {
    let path = temp("garbage");
    std::fs::write(&path, b"not a snapshot at all").unwrap();
    let err = read_snapshot(&path).unwrap_err().to_string();
    assert!(err.contains("snapshot"), "{err}");
    let _ = std::fs::remove_file(&path);
    // missing file: io error with the path in context
    let err = read_snapshot(&path).err().map(|e| format!("{e:#}")).unwrap();
    assert!(err.contains("reading snapshot"), "{err}");
}
