//! Acceptance properties for the implicit value engine (ISSUE 3 /
//! DESIGN.md §10): for every dataset, k, metric, and ingest partition,
//! the rank-space suffix-sum values equal the materialized matrix's
//! `diag + rowsums` to ≤ 1e-12 — verified against BOTH the fast dense
//! engine (`sti_knn`) and the brute-force `sti_exact` oracle — and the
//! implicit engine itself is **bit-reproducible** for any contiguous
//! partition of the test stream (the documented fixed summation order).
//! Plus the edge-case zoo (n=2, k=1, k=n, all-same-label, single test
//! point) and the implicit-mode session snapshot→restore round trip.

use stiknn::session::{Engine, SessionConfig, TopBy, ValuationSession};
use stiknn::shapley::sti_exact::sti_exact;
use stiknn::shapley::sti_knn::{sti_knn, StiParams};
use stiknn::shapley::values::{
    sti_point_values, sti_values, values_accumulate, ValueVector,
};
use stiknn::knn::distance::Metric;
use stiknn::util::matrix::Matrix;
use stiknn::util::prop::{check, Gen};

struct Problem {
    n: usize,
    d: usize,
    t: usize,
    k: usize,
    metric: Metric,
    train_x: Vec<f32>,
    train_y: Vec<i32>,
    test_x: Vec<f32>,
    test_y: Vec<i32>,
}

fn random_problem(g: &mut Gen) -> Problem {
    let n = 2 + g.usize_in(0, 34);
    let d = 1 + g.usize_in(0, 3);
    let t = 1 + g.usize_in(0, 20);
    let k = 1 + g.usize_in(0, n - 1);
    let classes = 2 + g.usize_in(0, 2);
    let metric = [Metric::SqEuclidean, Metric::Manhattan, Metric::Cosine]
        [g.usize_in(0, 2)];
    Problem {
        n,
        d,
        t,
        k,
        metric,
        train_x: g.features(n, d),
        train_y: g.labels(n, classes),
        test_x: g.features(t, d),
        test_y: g.labels(t, classes),
    }
}

fn params(p: &Problem) -> StiParams {
    StiParams {
        k: p.k,
        metric: p.metric,
    }
}

/// diag + full row sums of an averaged matrix — the dense reference.
fn diag_and_rowsums(m: &Matrix) -> (Vec<f64>, Vec<f64>) {
    let n = m.rows();
    let diag: Vec<f64> = (0..n).map(|i| m.get(i, i)).collect();
    let rowsum: Vec<f64> = (0..n).map(|i| m.row(i).iter().sum()).collect();
    (diag, rowsum)
}

/// A random contiguous partition of [0, t) into non-empty batches.
fn random_partition(g: &mut Gen, t: usize) -> Vec<(usize, usize)> {
    let mut cuts = vec![0, t];
    for _ in 0..g.usize_in(0, 5) {
        cuts.push(g.usize_in(0, t));
    }
    cuts.sort_unstable();
    cuts.dedup();
    cuts.windows(2).map(|w| (w[0], w[1])).collect()
}

#[test]
fn implicit_equals_dense_diag_plus_rowsums_for_any_shape() {
    check("implicit == dense diag+rowsums", 40, |g| {
        let p = random_problem(g);
        let m = sti_knn(&p.train_x, &p.train_y, p.d, &p.test_x, &p.test_y, &params(&p));
        let (diag, rowsum) = diag_and_rowsums(&m);
        let pv = sti_values(&p.train_x, &p.train_y, p.d, &p.test_x, &p.test_y, &params(&p));
        for i in 0..p.n {
            assert!(
                (pv.main[i] - diag[i]).abs() < 1e-12,
                "main[{i}] {} vs {} (n={} k={} t={} metric={:?})",
                pv.main[i], diag[i], p.n, p.k, p.t, p.metric
            );
            assert!(
                (pv.rowsum[i] - rowsum[i]).abs() < 1e-12,
                "rowsum[{i}] {} vs {} (n={} k={} t={} metric={:?})",
                pv.rowsum[i], rowsum[i], p.n, p.k, p.t, p.metric
            );
        }
    });
}

#[test]
fn implicit_matches_the_brute_force_oracle() {
    // Small n (2^n enumeration), every k: the implicit values against
    // Eq. 3 itself, not just against the fast dense engine.
    check("implicit == sti_exact diag+rowsums", 15, |g| {
        let n = 2 + g.usize_in(0, 8);
        let d = 1 + g.usize_in(0, 2);
        let t = 1 + g.usize_in(0, 4);
        let k = 1 + g.usize_in(0, n - 1);
        let train_x = g.features(n, d);
        let train_y = g.labels(n, 2);
        let test_x = g.features(t, d);
        let test_y = g.labels(t, 2);
        let exact = sti_exact(&train_x, &train_y, d, &test_x, &test_y, k);
        let (diag, rowsum) = diag_and_rowsums(&exact);
        let pv = sti_values(&train_x, &train_y, d, &test_x, &test_y, &StiParams::new(k));
        for i in 0..n {
            assert!((pv.main[i] - diag[i]).abs() < 1e-12, "main[{i}] n={n} k={k}");
            assert!(
                (pv.rowsum[i] - rowsum[i]).abs() < 1e-12,
                "rowsum[{i}] n={n} k={k}: {} vs {}",
                pv.rowsum[i],
                rowsum[i]
            );
        }
    });
}

#[test]
fn any_contiguous_partition_is_bit_identical() {
    check("implicit partition bit-reproducibility", 30, |g| {
        let p = random_problem(g);
        let mut one_shot = ValueVector::zeros(p.n);
        let w = values_accumulate(
            &p.train_x, &p.train_y, p.d, &p.test_x, &p.test_y, &params(&p), &mut one_shot,
        );
        assert_eq!(w, p.t as f64);
        let batches = random_partition(g, p.t);
        let mut parts = ValueVector::zeros(p.n);
        for &(lo, hi) in &batches {
            values_accumulate(
                &p.train_x,
                &p.train_y,
                p.d,
                &p.test_x[lo * p.d..hi * p.d],
                &p.test_y[lo..hi],
                &params(&p),
                &mut parts,
            );
        }
        for i in 0..p.n {
            assert_eq!(
                one_shot.main_raw()[i].to_bits(),
                parts.main_raw()[i].to_bits(),
                "main[{i}] diverged for partition {batches:?}"
            );
            assert_eq!(
                one_shot.inter_raw()[i].to_bits(),
                parts.inter_raw()[i].to_bits(),
                "inter[{i}] diverged for partition {batches:?}"
            );
        }
    });
}

#[test]
fn implicit_session_partition_with_snapshot_restore_matches_one_shot_bits() {
    // The session-layer acceptance property in implicit mode: any
    // contiguous ingest partition with a snapshot/restore cycle at an
    // arbitrary batch boundary is bit-identical to a one-shot ingest.
    check("implicit session snapshot equivalence", 15, |g| {
        let p = random_problem(g);
        let config = SessionConfig {
            metric: p.metric,
            ..SessionConfig::new(p.k)
        }
        .with_engine(Engine::Implicit);

        let mut reference =
            ValuationSession::new(p.train_x.clone(), p.train_y.clone(), p.d, config).unwrap();
        reference.ingest(&p.test_x, &p.test_y).unwrap();

        let batches = random_partition(g, p.t);
        let snap_after = g.usize_in(0, batches.len() - 1);
        let mut session =
            ValuationSession::new(p.train_x.clone(), p.train_y.clone(), p.d, config).unwrap();
        let path = std::env::temp_dir().join(format!(
            "stiknn_values_equiv_{}_{}.snap",
            std::process::id(),
            g.usize_in(0, usize::MAX / 2)
        ));
        for (bi, &(lo, hi)) in batches.iter().enumerate() {
            session
                .ingest(&p.test_x[lo * p.d..hi * p.d], &p.test_y[lo..hi])
                .unwrap();
            if bi == snap_after {
                session.save(&path).unwrap();
                session = ValuationSession::restore(
                    &path,
                    p.train_x.clone(),
                    p.train_y.clone(),
                    p.d,
                    config,
                )
                .unwrap();
                let _ = std::fs::remove_file(&path);
            }
        }
        assert_eq!(session.tests_seen(), p.t as u64);
        assert_eq!(session.engine(), Engine::Implicit);
        for by in [TopBy::Main, TopBy::RowSum] {
            let a = reference.point_values(by).unwrap();
            let b = session.point_values(by).unwrap();
            for i in 0..p.n {
                assert_eq!(
                    a[i].to_bits(),
                    b[i].to_bits(),
                    "{by:?}[{i}] diverged (partition {batches:?}, snap after {snap_after})"
                );
            }
        }
    });
}

#[test]
fn edge_cases_match_dense() {
    // n=2 / k=1 / k=n / all-same-label / single test point, deterministic.
    let cases: Vec<(Vec<f32>, Vec<i32>, Vec<f32>, Vec<i32>, usize, usize)> = vec![
        // (train_x, train_y, test_x, test_y, d, k)
        (vec![0.0, 1.0], vec![0, 1], vec![0.2], vec![0], 1, 1), // n=2, k=1
        (vec![0.0, 1.0], vec![1, 1], vec![0.9], vec![1], 1, 2), // n=2, k=n
        (
            vec![0.0, 0.5, 1.0, 1.5, 2.0],
            vec![1, 1, 1, 1, 1],
            vec![0.7, 1.9],
            vec![1, 1],
            1,
            3,
        ), // all same label
        (
            vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 1.0, 1.0],
            vec![0, 1, 1, 0],
            vec![0.25, 0.25],
            vec![0],
            2,
            4,
        ), // k = n, single test point
        (
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0],
            vec![0, 1, 0, 1, 0, 1],
            vec![2.2],
            vec![1],
            1,
            1,
        ), // k=1, single test point
    ];
    for (ti, (tx, ty, qx, qy, d, k)) in cases.into_iter().enumerate() {
        let params = StiParams::new(k);
        let m = sti_knn(&tx, &ty, d, &qx, &qy, &params);
        let (diag, rowsum) = diag_and_rowsums(&m);
        let pv = sti_values(&tx, &ty, d, &qx, &qy, &params);
        for i in 0..ty.len() {
            assert!(
                (pv.main[i] - diag[i]).abs() < 1e-12,
                "case {ti} main[{i}]"
            );
            assert!(
                (pv.rowsum[i] - rowsum[i]).abs() < 1e-12,
                "case {ti} rowsum[{i}]: {} vs {}",
                pv.rowsum[i],
                rowsum[i]
            );
        }
    }
}

#[test]
fn engine_switch_returns_identical_quantities() {
    check("sti_point_values engine switch", 20, |g| {
        let p = random_problem(g);
        let dense = sti_point_values(
            &p.train_x, &p.train_y, p.d, &p.test_x, &p.test_y, &params(&p), Engine::Dense,
        );
        let implicit = sti_point_values(
            &p.train_x, &p.train_y, p.d, &p.test_x, &p.test_y, &params(&p), Engine::Implicit,
        );
        for i in 0..p.n {
            assert!((dense.main[i] - implicit.main[i]).abs() < 1e-12);
            assert!((dense.rowsum[i] - implicit.rowsum[i]).abs() < 1e-12);
        }
    });
}

#[test]
fn implicit_session_agrees_with_dense_session_across_partitions() {
    check("session engine agreement", 15, |g| {
        let p = random_problem(g);
        let batches = random_partition(g, p.t);
        let base = SessionConfig {
            metric: p.metric,
            ..SessionConfig::new(p.k)
        };
        let mut dense =
            ValuationSession::new(p.train_x.clone(), p.train_y.clone(), p.d, base).unwrap();
        let mut imp = ValuationSession::new(
            p.train_x.clone(),
            p.train_y.clone(),
            p.d,
            base.with_engine(Engine::Implicit).with_retained_rows(true),
        )
        .unwrap();
        for &(lo, hi) in &batches {
            dense
                .ingest(&p.test_x[lo * p.d..hi * p.d], &p.test_y[lo..hi])
                .unwrap();
            imp.ingest(&p.test_x[lo * p.d..hi * p.d], &p.test_y[lo..hi])
                .unwrap();
        }
        // per-point values agree
        for by in [TopBy::Main, TopBy::RowSum] {
            let a = dense.point_values(by).unwrap();
            let b = imp.point_values(by).unwrap();
            for i in 0..p.n {
                assert!((a[i] - b[i]).abs() < 1e-12, "{by:?}[{i}]");
            }
        }
        // retained rows answer a sampled set of cells like the matrix
        for _ in 0..8 {
            let i = g.usize_in(0, p.n - 1);
            let j = g.usize_in(0, p.n - 1);
            let a = dense.cell(i, j).unwrap();
            let b = imp.cell(i, j).unwrap();
            assert!((a - b).abs() < 1e-12, "cell({i},{j}): {a} vs {b}");
        }
        // stats agree
        let (sa, sb) = (dense.stats(), imp.stats());
        assert!((sa.trace - sb.trace).abs() < 1e-12);
        assert!((sa.mean_offdiag - sb.mean_offdiag).abs() < 1e-12);
        assert!((sa.upper_sum - sb.upper_sum).abs() < 1e-12);
    });
}
