//! Perf probe for the §Perf pass: isolates the STI-KNN hot path at the
//! shapes the optimization log tracks. Not a paper experiment.
//!
//!     cargo run --release --example perf_probe

use stiknn::data::load_dataset;
use stiknn::shapley::sti_knn::{sti_knn, StiParams};

fn main() {
    for (n, t, k, reps) in [(600usize, 300usize, 5usize, 5u32), (1600, 64, 5, 3)] {
        let ds = load_dataset("circle", n, t, 5).unwrap();
        let params = StiParams::new(k);
        // warmup
        let _ = sti_knn(&ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, &params);
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(sti_knn(
                &ds.train_x, &ds.train_y, ds.d, &ds.test_x, &ds.test_y, &params,
            ));
        }
        let per = t0.elapsed() / reps;
        let cells = (n * n / 2) as f64 * t as f64;
        println!(
            "n={n} t={t} k={k}: {per:?}/run  {:.2} ns/pair-cell",
            per.as_nanos() as f64 / cells
        );
    }
}
