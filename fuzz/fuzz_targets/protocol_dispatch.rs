//! Fuzz NDJSON protocol dispatch against a live in-process session:
//! no panics, responses stay well-formed JSON, rejected frames leave
//! the session bit-identical. The property lives in `stiknn::verify`
//! (library code) — this target is just the libfuzzer adapter.
//! Repro: `cargo fuzz run protocol_dispatch <crasher-file>`.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    stiknn::verify::check_protocol_line(data);
});
