//! Fuzz the snapshot store's untrusted-byte surface: the cheap header
//! peek and the full checksum-verified restore. The properties live in
//! `stiknn::verify` (library code) — this target is just the libfuzzer
//! adapter. Repro: `cargo fuzz run snapshot_restore <crasher-file>`,
//! or promote the file into `tests/fuzz_corpus_replay.rs`.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    stiknn::verify::check_header_bytes(data);
    stiknn::verify::check_snapshot_bytes(data);
});
