"""AOT entry point: lower the L2 programs to HLO text + a JSON manifest.

Run once at build time (`make artifacts`); the Rust runtime loads the
artifacts via `HloModuleProto::from_text_file` and compiles them on the
PJRT CPU client. Python never runs on the request path.

Interchange format is HLO **text**, not `.serialize()`: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifact grid
-------------
One artifact per (program, n, d, b, k). The interaction program has a
fixed train-set size n (the coefficients of Eq. 6/7 depend on n, so train
padding would change the answer — test-block padding is handled by the
mask input instead). The default grid covers the paper's experiment
shapes (Circle = 600 train points, 2-D, k ∈ {5, 9, 20}) plus smaller
shapes used by the integration tests and the engine benches.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (n, d, b, k) grid for the `sti` program; the same (n, d, b) shapes are
# reused for the `knn_shapley` baseline program with its own k.
DEFAULT_GRID = [
    # integration-test shapes
    ("sti", 32, 2, 8, 3),
    ("sti", 64, 2, 16, 5),
    ("knn_shapley", 64, 2, 16, 5),
    # engine-bench shapes
    ("sti", 128, 8, 32, 5),
    ("sti", 256, 8, 32, 5),
    # paper Circle dataset (Figs. 3, 7): 300+300 train points, 2-D
    ("sti", 600, 2, 32, 5),
    ("sti", 600, 2, 32, 9),
    ("sti", 600, 2, 32, 20),
    # unbalanced Circle (Fig. 4): 60+300
    ("sti", 360, 2, 32, 5),
    ("knn_shapley", 600, 2, 32, 5),
]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (ids reassigned on parse)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_program(program: str, n: int, d: int, b: int, k: int) -> str:
    """Lower one (program, shape) instance to HLO text."""
    if program == "sti":
        fn = model.make_sti_fn(k=k, interpret=True)
    elif program == "knn_shapley":
        fn = model.make_knn_shapley_fn(k=k, interpret=True)
    else:
        raise ValueError(f"unknown program {program!r}")
    args = (
        jax.ShapeDtypeStruct((n, d), jnp.float32),  # train_x
        jax.ShapeDtypeStruct((n,), jnp.int32),      # train_y
        jax.ShapeDtypeStruct((b, d), jnp.float32),  # test_x
        jax.ShapeDtypeStruct((b,), jnp.int32),      # test_y
        jax.ShapeDtypeStruct((b,), jnp.float32),    # mask
    )
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def artifact_name(program: str, n: int, d: int, b: int, k: int) -> str:
    return f"{program}_n{n}_d{d}_b{b}_k{k}"


def build(out_dir: str, grid=None, force: bool = False) -> dict:
    grid = grid or DEFAULT_GRID
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for program, n, d, b, k in grid:
        name = artifact_name(program, n, d, b, k)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        if force or not os.path.exists(path):
            text = lower_program(program, n, d, b, k)
            with open(path, "w") as f:
                f.write(text)
            print(f"  wrote {fname} ({len(text)} chars)", file=sys.stderr)
        with open(path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()[:16]
        entries.append(
            {
                "name": name,
                "file": fname,
                "program": program,
                "n": n,
                "d": d,
                "b": b,
                "k": k,
                "sha256_16": digest,
                "inputs": [
                    {"name": "train_x", "shape": [n, d], "dtype": "f32"},
                    {"name": "train_y", "shape": [n], "dtype": "i32"},
                    {"name": "test_x", "shape": [b, d], "dtype": "f32"},
                    {"name": "test_y", "shape": [b], "dtype": "i32"},
                    {"name": "mask", "shape": [b], "dtype": "f32"},
                ],
                "outputs": (
                    [
                        {"name": "phi_sum", "shape": [n, n], "dtype": "f32"},
                        {"name": "weight", "shape": [1], "dtype": "f32"},
                    ]
                    if program == "sti"
                    else [
                        {"name": "s_sum", "shape": [n], "dtype": "f32"},
                        {"name": "weight", "shape": [1], "dtype": "f32"},
                    ]
                ),
            }
        )
    manifest = {"version": 1, "interchange": "hlo-text", "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(entries)} artifacts -> {out_dir}/manifest.json",
          file=sys.stderr)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true", help="re-lower even if present")
    args = ap.parse_args()
    build(args.out_dir, force=args.force)


if __name__ == "__main__":
    main()
