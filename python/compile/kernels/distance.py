"""Layer-1 Pallas kernel: tiled pairwise squared-euclidean distances.

Computes ``D[p, i] = ||test_x[p] - train_x[i]||^2`` for a block of test
points against the full training set, decomposed MXU-style as

    D = ||t||^2 ⊕ ||x||^2 − 2 · T Xᵀ

so the inner loop is a matmul that maps onto the TPU MXU systolic array
(the paper's hot substrate is rank computation; on GPU one would use a
threadblock-tiled GEMM — on TPU the equivalent is BlockSpec tiles feeding
the 128×128 MXU, with the rank-1 norm corrections on the VPU).

The kernel is tiled over (test-tile, train-tile); the feature dimension d
is kept whole inside a tile (d ≤ a few thousand fits VMEM comfortably:
a 128×d f32 tile at d=4096 is 2 MiB ≪ 16 MiB VMEM).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU perf is estimated analytically (DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes. 128 matches both the MXU edge and the f32 VPU lane tiling
# (8×128); test blocks are usually ≤ 64 so the row tile clamps to b.
ROW_TILE = 128
COL_TILE = 128


def _dist_kernel(t_ref, x_ref, t2_ref, x2_ref, o_ref):
    """One (row_tile × col_tile) output tile.

    t_ref:  (TR, d)  test-point features for this row tile
    x_ref:  (TC, d)  train-point features for this column tile
    t2_ref: (TR, 1)  precomputed ||t||^2
    x2_ref: (1, TC)  precomputed ||x||^2
    o_ref:  (TR, TC) output distances
    """
    # MXU: −2 · T Xᵀ.  Accumulate in f32 regardless of input dtype.
    cross = jax.lax.dot_general(
        t_ref[...],
        x_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # VPU: rank-1 corrections.
    o_ref[...] = t2_ref[...] + x2_ref[...] - 2.0 * cross


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pairwise_sq_dists(test_x, train_x, *, interpret=True):
    """Pairwise squared euclidean distances, shape (b, n), f32.

    Pads b and n up to the tile grid, runs the Pallas kernel, slices back.
    The norms ||t||², ||x||² are computed once outside the kernel (they are
    O(bd + nd), negligible next to the O(bnd) cross term) and streamed in
    per tile.
    """
    b, d = test_x.shape
    n, d2 = train_x.shape
    assert d == d2, f"feature dims differ: {d} vs {d2}"
    test_x = test_x.astype(jnp.float32)
    train_x = train_x.astype(jnp.float32)

    rt = min(ROW_TILE, max(8, b))
    ct = min(COL_TILE, max(8, n))
    tp = _pad_to(test_x, rt, 0)
    xp = _pad_to(train_x, ct, 0)
    bp, np_ = tp.shape[0], xp.shape[0]

    t2 = jnp.sum(tp * tp, axis=1, keepdims=True)          # (bp, 1)
    x2 = jnp.sum(xp * xp, axis=1, keepdims=True).T        # (1, np)

    grid = (bp // rt, np_ // ct)
    out = pl.pallas_call(
        _dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((ct, d), lambda i, j: (j, 0)),
            pl.BlockSpec((rt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, ct), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((rt, ct), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.float32),
        interpret=interpret,
    )(tp, xp, t2, x2)
    return out[:b, :n]
