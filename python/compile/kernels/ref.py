"""Pure-jnp / numpy oracles for the Pallas kernels and the full pipeline.

Everything in this module is a *correctness reference*:

- :func:`ref_pairwise_sq_dists`     — oracle for ``kernels.distance``
- :func:`ref_assembly`              — oracle for ``kernels.sti``
- :func:`alg1_superdiagonal`        — loop-faithful Algorithm 1 (lines 3-10)
- :func:`alg1_matrix_one_test`      — loop-faithful Algorithm 1 (full matrix,
  one test point), the gold standard the vectorized model is tested against
- :func:`ref_sti_block`             — full-pipeline reference for a test block
- :func:`valuation_u`               — Eq. (2) of the paper (used by the
  brute-force Eq. (3) oracle in the tests)

The loop-faithful functions intentionally mirror the paper's pseudocode
(1-based indexing in comments) rather than being vectorized, so that any
disagreement between the production path and the paper is attributable.
"""

from __future__ import annotations

import numpy as np


# ---------------------------------------------------------------------------
# Eq. (1)/(2): the KNN valuation function
# ---------------------------------------------------------------------------

def valuation_u(labels_sorted, y_test, subset, k):
    """Eq. (2): u_{y_test}(S) for S a set of *sorted-order* indices (0-based).

    ``labels_sorted`` are the train labels ordered from nearest to farthest
    from the test point; ``subset`` selects which train points are present.
    Only the ``min(k, |S|)`` nearest members of S vote.
    """
    members = sorted(subset)
    hits = sum(
        1 for idx in members[: min(k, len(members))] if labels_sorted[idx] == y_test
    )
    return hits / k


# ---------------------------------------------------------------------------
# Kernel oracles
# ---------------------------------------------------------------------------

def ref_pairwise_sq_dists(test_x, train_x):
    """Squared euclidean distances, shape (b, n). Oracle for distance kernel."""
    test_x = np.asarray(test_x, dtype=np.float64)
    train_x = np.asarray(train_x, dtype=np.float64)
    t2 = (test_x**2).sum(axis=1)[:, None]
    x2 = (train_x**2).sum(axis=1)[None, :]
    cross = test_x @ train_x.T
    return t2 + x2 - 2.0 * cross


def ref_assembly(ranks, colvals, diag, mask):
    """Oracle for the STI assembly kernel.

    Inputs are per-test-point, in ORIGINAL train order:
      ranks   (b, n) — rank of train point i in the distance sort for test p
      colvals (b, n) — superdiagonal value c_p at that point's own rank
      diag    (b, n) — main-term value u_p(i) (label match / k)
      mask    (b,)   — 1.0 for valid test points, 0.0 for padding

    Output (n, n): sum over p of mask_p * M_p where
      M_p[i, j] = diag_p[i]                    if i == j
                  colvals_p[i] if ranks_p[i] > ranks_p[j] else colvals_p[j]
    (i.e. the column value of whichever point is *farther* from the test
    point — Eq. (8): within a column of the sorted-order upper triangle all
    entries are equal, so the off-diagonal entry is c[max(rank_i, rank_j)].)
    """
    ranks = np.asarray(ranks)
    colvals = np.asarray(colvals, dtype=np.float64)
    diag = np.asarray(diag, dtype=np.float64)
    mask = np.asarray(mask, dtype=np.float64)
    b, n = ranks.shape
    out = np.zeros((n, n), dtype=np.float64)
    for p in range(b):
        ri = ranks[p][:, None]
        rj = ranks[p][None, :]
        m = np.where(ri > rj, colvals[p][:, None], colvals[p][None, :])
        np.fill_diagonal(m, diag[p])
        out += mask[p] * m
    return out


# ---------------------------------------------------------------------------
# Loop-faithful Algorithm 1
# ---------------------------------------------------------------------------

def alg1_superdiagonal(u, k):
    """Lines 3-10 of Algorithm 1 for one test point.

    ``u`` is the per-point valuation in sorted order (u[j] ∈ {0, 1/k}),
    0-based.  Returns ``c`` of length n+1, 1-based: ``c[j] = φ_{j-1,j}``
    for j = 2..n (c[0], c[1] unused, kept NaN).
    """
    u = np.asarray(u, dtype=np.float64)
    n = u.shape[0]
    if n < 2:
        raise ValueError("Algorithm 1 needs n >= 2")
    if k > n:
        raise ValueError(f"Algorithm 1 is exact only for k <= n (k={k}, n={n})")
    c = np.full(n + 1, np.nan)
    # Line 3: φ_{n-1,n} = -2(n-k)/(n(n-1)) u(α_n)
    c[n] = -2.0 * (n - k) / (n * (n - 1)) * u[n - 1]
    # Lines 4-10: for j = n down to 3, compute φ_{j-2,j-1} from φ_{j-1,j}
    for j in range(n, 2, -1):
        if j > k + 1:
            c[j - 1] = c[j] + 2.0 * (j - k - 1) / ((j - 2) * (j - 1)) * (
                u[j - 1] - u[j - 2]
            )
        else:
            c[j - 1] = c[j]
    return c


def alg1_matrix_one_test(labels_sorted, y_test, k, include_diag=True):
    """Full Algorithm 1 matrix for one test point, in SORTED order.

    Off-diagonal entries follow lines 11-14 (column equality, Eq. 8);
    the diagonal carries the main term φ_ii(u) = u(i) (Eq. 4/5) when
    ``include_diag`` is set, else zeros.
    """
    labels_sorted = np.asarray(labels_sorted)
    n = labels_sorted.shape[0]
    u = np.where(labels_sorted == y_test, 1.0 / k, 0.0)
    c = alg1_superdiagonal(u, k)
    phi = np.zeros((n, n), dtype=np.float64)
    for j in range(2, n + 1):  # 1-based column
        for i in range(1, j):  # 1-based row, upper triangle
            phi[i - 1, j - 1] = c[j]
            phi[j - 1, i - 1] = c[j]
    if include_diag:
        np.fill_diagonal(phi, u)
    return phi


def ref_sti_block(train_x, train_y, test_x, test_y, mask, k):
    """Full-pipeline reference: (phi_sum, weight) for a block of test points.

    ``phi_sum`` is the UNNORMALIZED sum over valid test points of the
    per-test matrices, scattered back into original train order; ``weight``
    is the number of valid test points.  The caller divides (Eq. 9).
    """
    train_x = np.asarray(train_x, dtype=np.float64)
    train_y = np.asarray(train_y)
    test_x = np.asarray(test_x, dtype=np.float64)
    test_y = np.asarray(test_y)
    mask = np.asarray(mask, dtype=np.float64)
    n = train_x.shape[0]
    dists = ref_pairwise_sq_dists(test_x, train_x)
    phi_sum = np.zeros((n, n), dtype=np.float64)
    for p in range(test_x.shape[0]):
        if mask[p] == 0.0:
            continue
        order = np.argsort(dists[p], kind="stable")
        m_sorted = alg1_matrix_one_test(train_y[order], test_y[p], k)
        inv = np.argsort(order)
        phi_sum += mask[p] * m_sorted[np.ix_(inv, inv)]
    return phi_sum, float(mask.sum())


# ---------------------------------------------------------------------------
# KNN-Shapley (Jia et al. 2019) — per-point values, used as oracle for the
# baseline program emitted alongside the interaction artifact.
# ---------------------------------------------------------------------------

def knn_shapley_one_test(labels_sorted, y_test, k):
    """Exact per-point Shapley values for the KNN valuation, one test point.

    Recursion from Jia et al. (2019), Theorem 1 (0-based arrays, 1-based
    math in comments):
      s_{α_n}  = 1[y_{α_n} = y]/n
      s_{α_i}  = s_{α_{i+1}} + (1[y_{α_i}=y] − 1[y_{α_{i+1}}=y])/k · min(k,i)/i
    Returns values in SORTED order.
    """
    labels_sorted = np.asarray(labels_sorted)
    n = labels_sorted.shape[0]
    match = (labels_sorted == y_test).astype(np.float64)
    s = np.zeros(n, dtype=np.float64)
    s[n - 1] = match[n - 1] / n
    for i in range(n - 1, 0, -1):  # 1-based i = n-1 .. 1
        s[i - 1] = s[i] + (match[i - 1] - match[i]) / k * min(k, i) / i
    return s
