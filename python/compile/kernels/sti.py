"""Layer-1 Pallas kernel: STI interaction-matrix assembly + accumulation.

This is the paper's O(t·n²) hot loop. For each test point p the full n×n
pair-interaction matrix (in ORIGINAL train order) is

    M_p[i, j] = diag_p[i]                                   if i == j
                colvals_p[i]  if ranks_p[i] > ranks_p[j]    else colvals_p[j]

where ``ranks_p[i]`` is the position of train point i in the distance sort
for test p and ``colvals_p[i]`` is the superdiagonal value c at that
position (Algorithm 1 lines 3–10, vectorized as a reversed cumsum in L2).
Eq. (8) of the paper (column equality in sorted order) is exactly what
makes the off-diagonal entry a *select* between the two points' own column
values — the farther point's column wins.

The kernel computes  OUT[i, j] = Σ_p mask_p · M_p[i, j]  tiled over the
(n×n) output. Per output tile it loops over the test-block dimension with
all operands resident in VMEM:

    VMEM per tile ≈ TILE² · 4 B (out) + 3 · b · TILE · 4 B (ranks/colvals/
    diag slices) — at TILE=256, b=64: 256 KiB + 192 KiB ≪ 16 MiB.

Everything is a VPU select/FMA — no MXU — so the roofline is memory-bound;
the tiling keeps each output tile's working set in VMEM with a single
HBM write per tile (see DESIGN.md §8 for the estimate).

``interpret=True``: CPU image cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 256


def _assembly_kernel(ri_ref, rj_ref, ci_ref, cj_ref, di_ref, mask_ref, o_ref):
    """One (TI × TJ) tile of the accumulated interaction matrix.

    ri_ref:   (b, TI) ranks for the row slice      (original order)
    rj_ref:   (b, TJ) ranks for the column slice
    ci_ref:   (b, TI) column values for the row slice
    cj_ref:   (b, TJ) column values for the column slice
    di_ref:   (b, TI) diagonal (main-term) values for the row slice
    mask_ref: (b, 1)  test-point validity weights
    o_ref:    (TI, TJ)

    The diagonal is handled inside the same kernel: where the global row
    index equals the global column index we substitute the main term.
    Global indices are reconstructed from the grid position.
    """
    ti = o_ref.shape[0]
    tj = o_ref.shape[1]
    gi = pl.program_id(0) * ti + jax.lax.broadcasted_iota(jnp.int32, (ti, tj), 0)
    gj = pl.program_id(1) * tj + jax.lax.broadcasted_iota(jnp.int32, (ti, tj), 1)
    on_diag = gi == gj

    ri = ri_ref[...]          # (b, TI)
    rj = rj_ref[...]          # (b, TJ)
    ci = ci_ref[...]
    cj = cj_ref[...]
    di = di_ref[...]
    w = mask_ref[...]         # (b, 1)

    # Broadcast to (b, TI, TJ): farther point's column value wins.
    farther_i = ri[:, :, None] > rj[:, None, :]
    off = jnp.where(farther_i, ci[:, :, None], cj[:, None, :])
    val = jnp.where(on_diag[None, :, :], di[:, :, None], off)
    o_ref[...] = jnp.sum(val * w[:, :, None], axis=0)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("interpret", "tile"))
def assemble_accumulate(ranks, colvals, diag, mask, *, interpret=True, tile=TILE):
    """OUT[i,j] = Σ_p mask_p · M_p[i,j]; see module docstring.

    ranks   (b, n) int32 — unique per row (a permutation of 0..n-1)
    colvals (b, n) f32
    diag    (b, n) f32
    mask    (b,)   f32
    returns (n, n) f32
    """
    b, n = ranks.shape
    t = min(tile, max(8, n))
    rp = _pad_to(ranks.astype(jnp.int32), t, 1)
    # Padded columns get rank -1 so they never win the "farther" select —
    # harmless, as padded outputs are sliced away anyway.
    if rp.shape[1] != n:
        rp = rp.at[:, n:].set(-1)
    cp = _pad_to(colvals.astype(jnp.float32), t, 1)
    dp = _pad_to(diag.astype(jnp.float32), t, 1)
    npad = rp.shape[1]
    grid = (npad // t, npad // t)
    out = pl.pallas_call(
        _assembly_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, t), lambda i, j: (0, i)),
            pl.BlockSpec((b, t), lambda i, j: (0, j)),
            pl.BlockSpec((b, t), lambda i, j: (0, i)),
            pl.BlockSpec((b, t), lambda i, j: (0, j)),
            pl.BlockSpec((b, t), lambda i, j: (0, i)),
            pl.BlockSpec((b, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t, t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((npad, npad), jnp.float32),
        interpret=interpret,
    )(rp, rp, cp, cp, dp, mask.astype(jnp.float32).reshape(b, 1))
    return out[:n, :n]
