"""Layer-2 JAX compute graph: the STI-KNN pipeline for a block of test points.

This is the vectorized form of Algorithm 1 (Belaid et al. 2023):

  1. pairwise distances test-block × train set      (Pallas, kernels.distance)
  2. per-test argsort → ranks                        (XLA sort)
  3. sorted label-match vector  u_j ∈ {0, 1/k}       (gather + compare)
  4. superdiagonal as a reversed cumulative sum      (Eq. 6/7 → cumsum)
  5. per-point column value in original order        (gather at own rank)
  6. O(b·n²) matrix assembly + masked accumulation   (Pallas, kernels.sti)

The block program returns the UNNORMALIZED sum over valid test points plus
the summed weight, so the Rust coordinator can merge partial results from
many blocks exactly (Eq. 9 linearity over the test set is what makes the
whole pipeline shard-parallel).

The reversed-cumsum reformulation of lines 3–10 of Algorithm 1: with
g(j) = 2(j−k−1)/((j−2)(j−1))·(u_j − u_{j−1}) for j > k+1 (else 0), the
superdiagonal is

    c_j := φ_{j−1,j} = φ_{n−1,n} + Σ_{m=j+1..n} g(m),   j = 2..n,

which is `phi_last + reverse_exclusive_cumsum(g)` — O(n) with no
sequential dependency chain beyond the scan XLA lowers cumsum to.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import distance as distance_kernel
from .kernels import sti as sti_kernel


def superdiagonal_batch(u_sorted, k):
    """Vectorized Algorithm-1 lines 3–10 for a batch.

    u_sorted: (b, n) f32, entries in {0, 1/k}, sorted nearest-first.
    Returns c: (b, n) f32 where c[:, j-1] (0-based j-1) = φ_{j−1,j} for the
    1-based column j = 2..n stored at index j−1; index 0 duplicates column 2
    (φ_{1,2}) so that `c[:, rank]` is the "own column value" of the point
    with that rank (rank 0's column value is never used off-diagonally as
    the max-rank of a pair is ≥ 1).
    """
    b, n = u_sorted.shape
    phi_last_only = -2.0 * (n - k) / (n * (n - 1.0)) * u_sorted[:, -1:]
    if n == 2:
        # Single column (φ_{1,2} = φ_{n−1,n}); duplicate for rank 0.
        return jnp.concatenate([phi_last_only, phi_last_only], axis=1)
    j = jnp.arange(3, n + 1, dtype=jnp.float32)          # 1-based j = 3..n
    coef = jnp.where(j > k + 1, 2.0 * (j - k - 1) / ((j - 2.0) * (j - 1.0)), 0.0)
    # g[:, m] corresponds to 1-based j = m+3: uses u_j − u_{j−1} = u0[j−1]−u0[j−2]
    g = coef[None, :] * (u_sorted[:, 2:] - u_sorted[:, 1:-1])   # (b, n-2)
    phi_last = -2.0 * (n - k) / (n * (n - 1.0)) * u_sorted[:, -1:]  # (b, 1)
    # c for column j (1-based, j=2..n): phi_last + sum_{m=j+1..n} g(m).
    # reverse-exclusive cumsum over g gives, at position of column j,
    # the sum of g for m > j.
    tail = jnp.cumsum(g[:, ::-1], axis=1)[:, ::-1]       # (b, n-2): Σ_{m≥j} g(m)
    col = jnp.concatenate(
        [tail + phi_last, phi_last], axis=1
    )                                                    # (b, n-1): columns 2..n
    # Prepend a copy for rank-0 (column "1" has no upper-triangle entries).
    return jnp.concatenate([col[:, :1], col], axis=1)    # (b, n)


def sti_block(train_x, train_y, test_x, test_y, mask, *, k, interpret=True):
    """STI-KNN partial result for one test block.

    train_x (n, d) f32 · train_y (n,) i32 · test_x (b, d) f32 ·
    test_y (b,) i32 · mask (b,) f32 (1 = valid, 0 = padding)

    Returns (phi_sum (n,n) f32, weight (1,) f32): sum over valid test
    points of the per-test interaction matrix (diagonal = main terms
    φ_ii(u) = u(i)), and the number of valid points.
    """
    n = train_x.shape[0]
    if k > n:
        raise ValueError(f"STI-KNN requires k <= n (k={k}, n={n})")

    dists = distance_kernel.pairwise_sq_dists(test_x, train_x, interpret=interpret)
    order = jnp.argsort(dists, axis=1, stable=True)       # (b, n) nearest-first
    ranks = jnp.argsort(order, axis=1, stable=True)       # (b, n) rank of point i

    labels_sorted = jnp.take_along_axis(
        jnp.broadcast_to(train_y[None, :], order.shape), order, axis=1
    )
    u_sorted = jnp.where(labels_sorted == test_y[:, None], 1.0 / k, 0.0).astype(
        jnp.float32
    )

    c = superdiagonal_batch(u_sorted, k)                  # (b, n) by rank
    colvals = jnp.take_along_axis(c, ranks, axis=1)       # (b, n) original order
    diag = jnp.where(train_y[None, :] == test_y[:, None], 1.0 / k, 0.0).astype(
        jnp.float32
    )                                                     # u(i), original order

    phi_sum = sti_kernel.assemble_accumulate(
        ranks, colvals, diag, mask, interpret=interpret
    )
    weight = jnp.sum(mask, dtype=jnp.float32).reshape(1)
    return phi_sum, weight


def knn_shapley_block(train_x, train_y, test_x, test_y, mask, *, k, interpret=True):
    """Per-point KNN-Shapley (Jia et al. 2019) partial sums for a test block.

    The baseline the paper compares complexity against. Recursion (sorted
    order, 1-based):  s_n = 1[y_n=y]/n,
    s_i = s_{i+1} + (1[y_i=y] − 1[y_{i+1}=y]) / k · min(k, i) / i
    — again a reversed cumulative sum.

    Returns (s_sum (n,) f32, weight (1,) f32), original train order.
    """
    n = train_x.shape[0]
    dists = distance_kernel.pairwise_sq_dists(test_x, train_x, interpret=interpret)
    order = jnp.argsort(dists, axis=1, stable=True)
    ranks = jnp.argsort(order, axis=1, stable=True)
    labels_sorted = jnp.take_along_axis(
        jnp.broadcast_to(train_y[None, :], order.shape), order, axis=1
    )
    match = (labels_sorted == test_y[:, None]).astype(jnp.float32)  # (b, n)

    i = jnp.arange(1, n, dtype=jnp.float32)               # 1-based i = 1..n-1
    step = (match[:, :-1] - match[:, 1:]) / k * jnp.minimum(k, i) / i  # (b, n-1)
    s_last = match[:, -1:] / n
    tail = jnp.cumsum(step[:, ::-1], axis=1)[:, ::-1]      # Σ_{m≥i} step(m)
    s_sorted = jnp.concatenate([tail + s_last, s_last], axis=1)       # (b, n)

    s_orig = jnp.take_along_axis(s_sorted, ranks, axis=1)
    s_sum = jnp.sum(s_orig * mask[:, None], axis=0)
    weight = jnp.sum(mask, dtype=jnp.float32).reshape(1)
    return s_sum, weight


def make_sti_fn(k, interpret=True):
    """Close over static parameters so jax.jit sees only array args."""

    @functools.wraps(sti_block)
    def fn(train_x, train_y, test_x, test_y, mask):
        return sti_block(
            train_x, train_y, test_x, test_y, mask, k=k, interpret=interpret
        )

    return fn


def make_knn_shapley_fn(k, interpret=True):
    @functools.wraps(knn_shapley_block)
    def fn(train_x, train_y, test_x, test_y, mask):
        return knn_shapley_block(
            train_x, train_y, test_x, test_y, mask, k=k, interpret=interpret
        )

    return fn
