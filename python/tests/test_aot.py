"""AOT pipeline tests: HLO text emission, manifest integrity, idempotence,
and numerical round-trip of the lowered computation through XLA (compiling
the emitted text back and executing it via the Python XLA client mirrors
what the Rust runtime does with the same artifact).
"""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref


SMALL_GRID = [("sti", 16, 2, 4, 3), ("knn_shapley", 16, 2, 4, 3)]


@pytest.fixture()
def built(tmp_path):
    manifest = aot.build(str(tmp_path), grid=SMALL_GRID, force=True)
    return tmp_path, manifest


class TestManifest:
    def test_entries_and_files(self, built):
        out, manifest = built
        assert manifest["interchange"] == "hlo-text"
        assert len(manifest["artifacts"]) == len(SMALL_GRID)
        for e in manifest["artifacts"]:
            p = out / e["file"]
            assert p.exists() and p.stat().st_size > 1000
            assert e["inputs"][0]["shape"] == [e["n"], e["d"]]

    def test_manifest_json_parses(self, built):
        out, _ = built
        with open(out / "manifest.json") as f:
            m = json.load(f)
        names = {e["name"] for e in m["artifacts"]}
        assert "sti_n16_d2_b4_k3" in names

    def test_idempotent_no_rewrite(self, built):
        out, _ = built
        f = out / "sti_n16_d2_b4_k3.hlo.txt"
        mtime = f.stat().st_mtime_ns
        aot.build(str(out), grid=SMALL_GRID, force=False)
        assert f.stat().st_mtime_ns == mtime, "artifact rewritten despite no change"

    def test_hlo_text_is_parseable_hlo(self, built):
        out, manifest = built
        text = (out / manifest["artifacts"][0]["file"]).read_text()
        assert text.startswith("HloModule"), text[:50]


class TestRoundTrip:
    """Parse the emitted HLO text back and validate the program signature.

    Note: numerical *execution* of the HLO-proto artifact is covered by the
    Rust runtime integration tests (rust/tests/runtime_equivalence.rs) —
    modern jaxlib clients only accept StableHLO, whereas the artifact format
    targets xla_extension 0.5.1's HLO-text parser, which is what the Rust
    `xla` crate uses."""

    def test_sti_artifact_parses_with_expected_signature(self, built):
        out, manifest = built
        entry = next(e for e in manifest["artifacts"] if e["program"] == "sti")
        n, d, b = entry["n"], entry["d"], entry["b"]

        text = (out / entry["file"]).read_text()
        hm = xc._xla.hlo_module_from_text(text)  # raises on malformed HLO
        comp = xc.XlaComputation(hm.as_serialized_hlo_module_proto())
        shape = comp.program_shape()
        params = shape.parameter_shapes()
        assert [tuple(p.dimensions()) for p in params] == [
            (n, d), (n,), (b, d), (b,), (b,)
        ]
        result = shape.result_shape()
        assert result.is_tuple()
        parts = result.tuple_shapes()
        assert tuple(parts[0].dimensions()) == (n, n)
        assert tuple(parts[1].dimensions()) == (1,)

    def test_jit_model_matches_reference_at_artifact_shape(self, built):
        """The jitted function that was lowered produces reference numbers at
        exactly the artifact shape (same trace => same HLO semantics)."""
        _, manifest = built
        entry = next(e for e in manifest["artifacts"] if e["program"] == "sti")
        n, d, b, k = entry["n"], entry["d"], entry["b"], entry["k"]
        rng = np.random.default_rng(0)
        tx = rng.normal(size=(n, d)).astype(np.float32)
        ty = rng.integers(0, 2, size=n).astype(np.int32)
        sx = rng.normal(size=(b, d)).astype(np.float32)
        sy = rng.integers(0, 2, size=b).astype(np.int32)
        mask = np.ones(b, dtype=np.float32)
        fn = jax.jit(model.make_sti_fn(k=k))
        phi, w = fn(jnp.array(tx), jnp.array(ty), jnp.array(sx),
                    jnp.array(sy), jnp.array(mask))
        want, want_w = ref.ref_sti_block(tx, ty, sx, sy, mask, k)
        np.testing.assert_allclose(np.asarray(phi), want, rtol=1e-4, atol=1e-5)
        assert float(w[0]) == pytest.approx(want_w)
