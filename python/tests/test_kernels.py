"""L1 kernel tests: Pallas (interpret=True) vs pure-numpy oracles.

Hypothesis sweeps shapes, dtypes and k; every property asserts
`assert_allclose` against ref.py as required for the correctness signal.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import distance, ref, sti


settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


shape_strategy = st.tuples(
    st.integers(min_value=1, max_value=40),   # b
    st.integers(min_value=2, max_value=70),   # n
    st.integers(min_value=1, max_value=9),    # d
)


class TestDistanceKernel:
    @given(shape=shape_strategy, seed=st.integers(0, 2**31 - 1),
           dtype=st.sampled_from([np.float32, np.float64]))
    def test_matches_reference(self, shape, seed, dtype):
        b, n, d = shape
        rng = np.random.default_rng(seed)
        tx = rng.normal(scale=3.0, size=(b, d)).astype(dtype)
        xx = rng.normal(scale=3.0, size=(n, d)).astype(dtype)
        got = np.asarray(distance.pairwise_sq_dists(jnp.array(tx), jnp.array(xx)))
        want = ref.ref_pairwise_sq_dists(tx, xx)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_exact_zero_for_identical_points(self):
        x = np.array([[1.5, -2.0], [0.0, 0.0], [3.0, 4.0]], dtype=np.float32)
        got = np.asarray(distance.pairwise_sq_dists(jnp.array(x), jnp.array(x)))
        np.testing.assert_allclose(np.diag(got), 0.0, atol=1e-5)

    def test_tiling_boundary_exact_tile_multiple(self):
        # b and n exactly at tile multiples exercise the no-padding path.
        rng = np.random.default_rng(7)
        tx = rng.normal(size=(distance.ROW_TILE, 3)).astype(np.float32)
        xx = rng.normal(size=(distance.COL_TILE * 2, 3)).astype(np.float32)
        got = np.asarray(distance.pairwise_sq_dists(jnp.array(tx), jnp.array(xx)))
        want = ref.ref_pairwise_sq_dists(tx, xx)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_known_values(self):
        t = np.array([[0.0, 0.0], [1.0, 1.0]], dtype=np.float32)
        x = np.array([[3.0, 4.0], [1.0, 0.0]], dtype=np.float32)
        got = np.asarray(distance.pairwise_sq_dists(jnp.array(t), jnp.array(x)))
        np.testing.assert_allclose(got, [[25.0, 1.0], [13.0, 1.0]], atol=1e-5)


def _random_assembly_inputs(rng, b, n):
    ranks = np.stack([rng.permutation(n) for _ in range(b)]).astype(np.int32)
    colvals = rng.normal(size=(b, n)).astype(np.float32)
    diag = rng.normal(size=(b, n)).astype(np.float32)
    mask = (rng.random(b) > 0.3).astype(np.float32)
    return ranks, colvals, diag, mask


class TestAssemblyKernel:
    @given(b=st.integers(1, 12), n=st.integers(2, 60), seed=st.integers(0, 2**31 - 1))
    def test_matches_reference(self, b, n, seed):
        rng = np.random.default_rng(seed)
        ranks, colvals, diag, mask = _random_assembly_inputs(rng, b, n)
        got = np.asarray(
            sti.assemble_accumulate(
                jnp.array(ranks), jnp.array(colvals), jnp.array(diag), jnp.array(mask)
            )
        )
        want = ref.ref_assembly(ranks, colvals, diag, mask)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_multi_tile_grid(self):
        # n larger than one tile exercises the cross-tile diagonal logic.
        rng = np.random.default_rng(3)
        b, n = 4, sti.TILE + 37
        ranks, colvals, diag, mask = _random_assembly_inputs(rng, b, n)
        got = np.asarray(
            sti.assemble_accumulate(
                jnp.array(ranks), jnp.array(colvals), jnp.array(diag), jnp.array(mask),
            )
        )
        want = ref.ref_assembly(ranks, colvals, diag, mask)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_small_tile_override(self):
        rng = np.random.default_rng(5)
        ranks, colvals, diag, mask = _random_assembly_inputs(rng, 3, 50)
        got = np.asarray(
            sti.assemble_accumulate(
                jnp.array(ranks), jnp.array(colvals), jnp.array(diag), jnp.array(mask),
                tile=16,
            )
        )
        want = ref.ref_assembly(ranks, colvals, diag, mask)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_zero_mask_gives_zero(self):
        rng = np.random.default_rng(11)
        ranks, colvals, diag, _ = _random_assembly_inputs(rng, 5, 20)
        got = np.asarray(
            sti.assemble_accumulate(
                jnp.array(ranks), jnp.array(colvals), jnp.array(diag),
                jnp.zeros(5, dtype=jnp.float32),
            )
        )
        np.testing.assert_allclose(got, 0.0, atol=0.0)

    def test_output_symmetric_when_inputs_make_it_so(self):
        # The off-diagonal select is symmetric in (i, j) by construction.
        rng = np.random.default_rng(13)
        ranks, colvals, diag, mask = _random_assembly_inputs(rng, 6, 33)
        got = np.asarray(
            sti.assemble_accumulate(
                jnp.array(ranks), jnp.array(colvals), jnp.array(diag), jnp.array(mask)
            )
        )
        off = got - np.diag(np.diag(got))
        np.testing.assert_allclose(off, off.T, rtol=1e-6, atol=1e-6)
