"""L2 model tests: the vectorized STI-KNN pipeline vs the loop-faithful
Algorithm 1 reference, plus the paper's structural properties (axioms,
column equality, Corollary 1).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")


def _dataset(rng, n, b, d, classes=2):
    tx = rng.normal(scale=2.0, size=(n, d)).astype(np.float32)
    ty = rng.integers(0, classes, size=n).astype(np.int32)
    sx = rng.normal(scale=2.0, size=(b, d)).astype(np.float32)
    sy = rng.integers(0, classes, size=b).astype(np.int32)
    mask = (rng.random(b) > 0.25).astype(np.float32)
    if mask.sum() == 0:
        mask[0] = 1.0
    return tx, ty, sx, sy, mask


class TestSuperdiagonal:
    @given(n=st.integers(2, 50), kk=st.integers(1, 50), seed=st.integers(0, 10**6))
    def test_matches_loop_faithful(self, n, kk, seed):
        k = min(kk, n)
        rng = np.random.default_rng(seed)
        u = np.where(rng.random(n) < 0.5, 1.0 / k, 0.0).astype(np.float32)
        got = np.asarray(model.superdiagonal_batch(jnp.array(u[None, :]), k))[0]
        want_c = ref.alg1_superdiagonal(u, k)  # 1-based, c[j] for j=2..n
        # model layout: index r (rank, 0-based) -> c_{r+1}; index 0 dups c_2
        for rank in range(1, n):
            assert got[rank] == pytest.approx(want_c[rank + 1], abs=1e-6), (
                f"rank {rank}: {got[rank]} vs {want_c[rank + 1]}"
            )
        assert got[0] == pytest.approx(want_c[2], abs=1e-6)


class TestStiBlock:
    @given(
        n=st.integers(2, 40),
        b=st.integers(1, 10),
        d=st.integers(1, 5),
        kk=st.integers(1, 40),
        classes=st.integers(2, 4),
        seed=st.integers(0, 10**6),
    )
    def test_matches_reference_pipeline(self, n, b, d, kk, classes, seed):
        k = min(kk, n)
        rng = np.random.default_rng(seed)
        tx, ty, sx, sy, mask = _dataset(rng, n, b, d, classes)
        phi, w = model.sti_block(
            jnp.array(tx), jnp.array(ty), jnp.array(sx), jnp.array(sy),
            jnp.array(mask), k=k,
        )
        want, want_w = ref.ref_sti_block(tx, ty, sx, sy, mask, k)
        assert float(w[0]) == pytest.approx(want_w)
        np.testing.assert_allclose(np.asarray(phi), want, rtol=1e-4, atol=1e-5)

    def test_k_greater_than_n_rejected(self):
        rng = np.random.default_rng(0)
        tx, ty, sx, sy, mask = _dataset(rng, 5, 2, 2)
        with pytest.raises(ValueError, match="k <= n"):
            model.sti_block(
                jnp.array(tx), jnp.array(ty), jnp.array(sx), jnp.array(sy),
                jnp.array(mask), k=6,
            )

    def test_efficiency_axiom_per_test_point(self):
        """Upper triangle incl. diagonal sums to u_{y_test}(N) exactly
        (the precise form of the paper's efficiency claim, DESIGN.md §1)."""
        rng = np.random.default_rng(42)
        n, k = 15, 4
        tx, ty, sx, sy, _ = _dataset(rng, n, 6, 3)
        for p in range(6):
            mask = np.zeros(6, dtype=np.float32)
            mask[p] = 1.0
            phi, _ = model.sti_block(
                jnp.array(tx), jnp.array(ty), jnp.array(sx), jnp.array(sy),
                jnp.array(mask), k=k,
            )
            phi = np.asarray(phi, dtype=np.float64)
            d = ref.ref_pairwise_sq_dists(sx[p : p + 1], tx)[0]
            order = np.argsort(d, kind="stable")
            v_n = ref.valuation_u(list(ty[order]), sy[p], set(range(n)), k)
            assert np.triu(phi).sum() == pytest.approx(v_n, abs=1e-5)

    def test_column_equality_single_test_point(self):
        """Eq. (8): for one test point, in sorted order every upper-triangle
        column is constant."""
        rng = np.random.default_rng(1)
        n, k = 12, 3
        tx, ty, sx, sy, _ = _dataset(rng, n, 1, 2)
        mask = np.ones(1, dtype=np.float32)
        phi, _ = model.sti_block(
            jnp.array(tx), jnp.array(ty), jnp.array(sx), jnp.array(sy),
            jnp.array(mask), k=k,
        )
        phi = np.asarray(phi)
        d = ref.ref_pairwise_sq_dists(sx, tx)[0]
        order = np.argsort(d, kind="stable")
        m_sorted = phi[np.ix_(order, order)]
        for j in range(1, n):
            col = m_sorted[:j, j]
            np.testing.assert_allclose(col, col[0], atol=1e-6)

    def test_symmetry(self):
        rng = np.random.default_rng(2)
        tx, ty, sx, sy, mask = _dataset(rng, 25, 8, 3)
        phi, _ = model.sti_block(
            jnp.array(tx), jnp.array(ty), jnp.array(sx), jnp.array(sy),
            jnp.array(mask), k=5,
        )
        phi = np.asarray(phi)
        np.testing.assert_allclose(phi, phi.T, atol=1e-6)

    def test_main_terms_nonnegative(self):
        rng = np.random.default_rng(3)
        tx, ty, sx, sy, mask = _dataset(rng, 20, 10, 2)
        phi, _ = model.sti_block(
            jnp.array(tx), jnp.array(ty), jnp.array(sx), jnp.array(sy),
            jnp.array(mask), k=5,
        )
        assert (np.diag(np.asarray(phi)) >= -1e-7).all()

    def test_block_linearity(self):
        """Eq. (9): the block result equals the sum of single-point results —
        the property the coordinator's shard-merge relies on."""
        rng = np.random.default_rng(4)
        n, b, k = 18, 5, 3
        tx, ty, sx, sy, _ = _dataset(rng, n, b, 2)
        mask = np.ones(b, dtype=np.float32)
        whole, w = model.sti_block(
            jnp.array(tx), jnp.array(ty), jnp.array(sx), jnp.array(sy),
            jnp.array(mask), k=k,
        )
        acc = np.zeros((n, n))
        for p in range(b):
            m = np.zeros(b, dtype=np.float32)
            m[p] = 1.0
            part, _ = model.sti_block(
                jnp.array(tx), jnp.array(ty), jnp.array(sx), jnp.array(sy),
                jnp.array(m), k=k,
            )
            acc += np.asarray(part, dtype=np.float64)
        np.testing.assert_allclose(np.asarray(whole), acc, rtol=1e-4, atol=1e-5)


class TestKnnShapleyBlock:
    @given(
        n=st.integers(2, 40),
        b=st.integers(1, 10),
        kk=st.integers(1, 40),
        seed=st.integers(0, 10**6),
    )
    def test_matches_loop_reference(self, n, b, kk, seed):
        k = min(kk, n)
        rng = np.random.default_rng(seed)
        tx, ty, sx, sy, mask = _dataset(rng, n, b, 3)
        s, w = model.knn_shapley_block(
            jnp.array(tx), jnp.array(ty), jnp.array(sx), jnp.array(sy),
            jnp.array(mask), k=k,
        )
        d = ref.ref_pairwise_sq_dists(sx, tx)
        want = np.zeros(n)
        for p in range(b):
            if mask[p] == 0:
                continue
            order = np.argsort(d[p], kind="stable")
            sv = ref.knn_shapley_one_test(ty[order], sy[p], k)
            want += sv[np.argsort(order)]
        np.testing.assert_allclose(np.asarray(s), want, rtol=1e-4, atol=1e-5)

    def test_per_test_efficiency(self):
        """Per-point Shapley values sum to u_{y_test}(N) for each test point."""
        rng = np.random.default_rng(9)
        n, k = 20, 5
        tx, ty, sx, sy, _ = _dataset(rng, n, 4, 2)
        for p in range(4):
            mask = np.zeros(4, dtype=np.float32)
            mask[p] = 1.0
            s, _ = model.knn_shapley_block(
                jnp.array(tx), jnp.array(ty), jnp.array(sx), jnp.array(sy),
                jnp.array(mask), k=k,
            )
            d = ref.ref_pairwise_sq_dists(sx[p : p + 1], tx)[0]
            order = np.argsort(d, kind="stable")
            v_n = ref.valuation_u(list(ty[order]), sy[p], set(range(n)), k)
            assert float(np.asarray(s).sum()) == pytest.approx(v_n, abs=1e-5)


class TestCorollary1:
    def test_std_inversely_proportional_to_k(self):
        """Corollary 1: std of the STI values shrinks as k grows."""
        rng = np.random.default_rng(17)
        n, b = 60, 16
        tx, ty, sx, sy, _ = _dataset(rng, n, b, 2)
        mask = np.ones(b, dtype=np.float32)
        stds = []
        for k in (3, 6, 12, 24):
            phi, w = model.sti_block(
                jnp.array(tx), jnp.array(ty), jnp.array(sx), jnp.array(sy),
                jnp.array(mask), k=k,
            )
            m = np.asarray(phi) / float(w[0])
            stds.append(m[np.triu_indices(n, 1)].std())
        assert stds == sorted(stds, reverse=True), f"std not decreasing in k: {stds}"
