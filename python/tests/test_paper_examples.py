"""Golden tests straight from the paper's worked examples (§2.1, §2.2).

These pin the valuation function (Eqs. 1-2), the brute-force STI (Eq. 3)
and Algorithm 1 to the numbers printed in the paper — and document the one
place the paper's own arithmetic is inconsistent (Fig. 2, see
DESIGN.md §1 and EXPERIMENTS.md).
"""

import itertools
import math

import numpy as np
import pytest

from compile.kernels import ref


def brute_phi(labels_sorted, y_test, i, j, k):
    """Eq. (3), one test point, 0-based sorted indices, i != j."""
    n = len(labels_sorted)
    rest = [p for p in range(n) if p not in (i, j)]
    acc = 0.0
    for s in range(0, n - 1):
        coeff = 1.0 / math.comb(n - 1, s)
        for S in itertools.combinations(rest, s):
            S = set(S)
            acc += coeff * (
                ref.valuation_u(labels_sorted, y_test, S | {i, j}, k)
                - ref.valuation_u(labels_sorted, y_test, S | {i}, k)
                - ref.valuation_u(labels_sorted, y_test, S | {j}, k)
                + ref.valuation_u(labels_sorted, y_test, S, k)
            )
    return 2.0 / n * acc


class TestFig1:
    """§2.1: k=3, one test point, 4 train points sorted by distance with
    labels (matching, non-matching, matching, matching) — the figure
    shows v(N) = 2/3 and the listed singleton/triple values
    (u({1,3,4}) = 3/3 forces points 1, 3, 4 to all match y_test)."""

    labels = [1, 0, 1, 1]  # label 1 == y_test
    y = 1
    k = 3

    def test_v_full_train_set(self):
        assert ref.valuation_u(self.labels, self.y, {0, 1, 2, 3}, self.k) == pytest.approx(2 / 3)

    def test_v_singletons(self):
        assert ref.valuation_u(self.labels, self.y, {0}, self.k) == pytest.approx(1 / 3)
        assert ref.valuation_u(self.labels, self.y, {1}, self.k) == pytest.approx(0.0)

    def test_v_triple(self):
        # u({1,3,4}) = 3/3 (1-based) -> 0-based {0,2,3}
        assert ref.valuation_u(self.labels, self.y, {0, 2, 3}, self.k) == pytest.approx(1.0)

    def test_only_k_nearest_vote(self):
        # adding the 4th point does not change the score: min(k, s) voting
        assert ref.valuation_u(self.labels, self.y, {0, 1, 2}, self.k) == pytest.approx(
            ref.valuation_u(self.labels, self.y, {0, 1, 2, 3}, self.k)
        )


class TestFig2:
    """§2.2: the paper's interaction example claims φ_{1,2} = 1/6 for k=2,
    n=4, via intermediate I-terms. An exhaustive search over all 2^4 binary
    labelings x 2 test labels shows NO labeling reproduces all printed
    I-terms (e.g. "I = 1/2 − 1/2 − 2/2 + 1/2 = 1/2" is not internally
    consistent arithmetic). We therefore pin (a) that inconsistency, and
    (b) that for EVERY labeling, Algorithm 1 equals brute-force Eq. (3) —
    which is the substantive claim of the section."""

    def test_no_labeling_matches_printed_terms(self):
        k = 2
        consistent = []
        for labels in itertools.product([0, 1], repeat=4):
            for y in (0, 1):
                checks = [
                    (ref.valuation_u(labels, y, {0, 1, 2, 3}, k), 0.5),   # v(S∪{i,j}), S={3,4}
                    (ref.valuation_u(labels, y, {0, 2, 3}, k), 0.5),      # v(S∪{i})
                    (ref.valuation_u(labels, y, {1, 2, 3}, k), 0.0),      # v(S∪{j})
                    (ref.valuation_u(labels, y, {2, 3}, k), 0.5),         # v(S)
                    (ref.valuation_u(labels, y, {0, 1, 2}, k), 0.5),      # S={3}
                    (ref.valuation_u(labels, y, {0, 2}, k), 0.0),
                    (ref.valuation_u(labels, y, {1, 2}, k), 0.5),
                    (ref.valuation_u(labels, y, {2}, k), 0.0),
                ]
                if all(abs(a - b) < 1e-12 for a, b in checks):
                    consistent.append((labels, y))
        assert consistent == [], (
            "the paper's Fig. 2 I-terms unexpectedly became satisfiable"
        )

    def test_algorithm1_equals_bruteforce_for_all_fig2_labelings(self):
        k = 2
        for labels in itertools.product([0, 1], repeat=4):
            for y in (0, 1):
                m = ref.alg1_matrix_one_test(list(labels), y, k, include_diag=False)
                for i in range(4):
                    for j in range(4):
                        if i != j:
                            assert m[i, j] == pytest.approx(
                                brute_phi(list(labels), y, i, j, k), abs=1e-12
                            )


class TestEq6LastTerm:
    """Eq. (6): φ_{n-1,n} = −2(n−k)/(n(n−1))·u(α_n)."""

    @pytest.mark.parametrize("n,k", [(4, 2), (6, 3), (8, 5), (5, 5)])
    def test_matches_bruteforce(self, n, k):
        rng = np.random.default_rng(n * 31 + k)
        labels = list(rng.integers(0, 2, size=n))
        y = 1
        expected = brute_phi(labels, y, n - 2, n - 1, k)
        u_n = (1.0 / k) if labels[n - 1] == y else 0.0
        closed = -2.0 * (n - k) / (n * (n - 1)) * u_n
        assert closed == pytest.approx(expected, abs=1e-12)


class TestEfficiencyAxiom:
    """§3.2: the sum of the STI values equals the test score. The precise
    statement (verified against brute force): the UPPER TRIANGLE INCLUDING
    THE DIAGONAL sums to v(N) − v(∅) = v(N)."""

    @pytest.mark.parametrize("seed", range(5))
    def test_upper_triangle_sums_to_vN(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(4, 9))
        k = int(rng.integers(1, n + 1))
        labels = list(rng.integers(0, 2, size=n))
        y = int(rng.integers(0, 2))
        m = ref.alg1_matrix_one_test(labels, y, k)
        v_n = ref.valuation_u(labels, y, set(range(n)), k)
        assert np.triu(m).sum() == pytest.approx(v_n, abs=1e-12)
