//! Engine comparison: pure-Rust Algorithm 1 vs the AOT XLA artifact
//! (L1 Pallas + L2 JAX compiled through PJRT) on artifact shapes —
//! same numbers, different substrates (EXPERIMENTS.md §E2E / §Perf).
//!
//! Requires `make artifacts`.
//!
//!     cargo bench --bench engines

use std::path::Path;
use stiknn::bench::{quick, Suite};
use stiknn::report::table::Table;
use stiknn::runtime::{executor_for, Manifest};
use stiknn::shapley::sti_knn::{sti_knn_partial, StiParams};
use stiknn::util::rng::Rng;

fn main() {
    let dir = Path::new("artifacts");
    let Ok(manifest) = Manifest::load(dir) else {
        eprintln!("artifacts/ missing — run `make artifacts` first; skipping");
        return;
    };

    let mut suite = Suite::new("engines on artifact shapes").with_config(quick());
    let mut table = Table::new(&["shape", "rust", "xla", "xla/rust", "max|Δ|"]);

    for spec in manifest.of_program("sti") {
        let (n, d, b, k) = (spec.n, spec.d, spec.b, spec.k);
        let mut rng = Rng::new(7);
        let tx: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let ty: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let sx: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
        let sy: Vec<i32> = (0..b).map(|_| rng.below(2) as i32).collect();

        let params = StiParams::new(k);
        let mr = suite.bench(&format!("rust {}", spec.name), || {
            sti_knn_partial(&tx, &ty, d, &sx, &sy, &params)
        });
        let rust_secs = mr.mean_secs();

        let exec = executor_for(&manifest, "sti", n, d, k).unwrap();
        let mx = suite.bench(&format!("xla  {}", spec.name), || {
            exec.run_block(&tx, &ty, &sx, &sy).unwrap()
        });
        let xla_secs = mx.mean_secs();

        let (phi_r, _) = sti_knn_partial(&tx, &ty, d, &sx, &sy, &params);
        let (phi_x, _) = exec.run_block(&tx, &ty, &sx, &sy).unwrap();

        table.row(&[
            format!("n={n} d={d} b={b} k={k}"),
            stiknn::util::timer::fmt_duration(mr.mean),
            stiknn::util::timer::fmt_duration(mx.mean),
            format!("{:.1}x", xla_secs / rust_secs),
            format!("{:.1e}", phi_r.max_abs_diff(&phi_x)),
        ]);
    }
    println!("{}", suite.render());
    println!("\nengine comparison per block (EXPERIMENTS.md §Perf L2):\n{}", table.render());
}
