//! L3 coordinator throughput: worker/block-size sweep on the end-to-end
//! valuation pipeline (rust engine) — the scaling behaviour the perf pass
//! optimizes (EXPERIMENTS.md §Perf).
//!
//!     cargo bench --bench pipeline

use stiknn::bench::{quick, Suite};
use stiknn::coordinator::{run_job, ValuationJob};
use stiknn::data::load_dataset;
use stiknn::report::table::Table;

fn main() {
    let ds = load_dataset("circle", 600, 300, 5).unwrap();
    let k = 5;

    let mut suite = Suite::new("pipeline (circle n=600, t=300, k=5)").with_config(quick());
    let mut table = Table::new(&["workers", "block", "mean wall", "speedup vs 1 worker"]);
    let mut base = None;
    for workers in [1usize, 2, 4, 8] {
        for block in [8usize, 32] {
            let job = ValuationJob::new(k).with_workers(workers).with_block_size(block);
            let m = suite.bench(&format!("workers={workers} block={block}"), || {
                run_job(&ds, &job).unwrap()
            });
            let secs = m.mean_secs();
            if workers == 1 && block == 32 {
                base = Some(secs);
            }
            table.row(&[
                workers.to_string(),
                block.to_string(),
                stiknn::util::timer::fmt_duration(m.mean),
                base.map(|b| format!("{:.2}x", b / secs)).unwrap_or_default(),
            ]);
        }
    }
    println!("{}", suite.render());
    println!("\nscaling table (EXPERIMENTS.md §Perf L3):\n{}", table.render());
}
