//! Analysis suite over computed interaction matrices — the paper's §3.2
//! and §4 experiments as reusable components.

pub mod acquisition;
pub mod ksens;
pub mod mislabel;
pub mod redundancy;
pub mod removal;
pub mod structure;

pub use ksens::{k_sensitivity, KSensReport};
pub use mislabel::{mislabel_scores, MislabelReport};
pub use structure::block_structure;
