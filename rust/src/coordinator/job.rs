//! Valuation job and result types, plus the sharding plan.

use crate::data::Dataset;
use crate::knn::distance::Metric;
use crate::runtime::Engine;
use crate::util::matrix::Matrix;
use std::time::Duration;

/// A complete valuation request against one dataset.
#[derive(Clone, Debug)]
pub struct ValuationJob {
    pub k: usize,
    pub engine: Engine,
    /// Test points per shard (block). For the XLA engine this is clamped
    /// to the artifact's baked block size.
    pub block_size: usize,
    pub workers: usize,
    pub metric: Metric,
    /// Bounded-queue capacity as a multiple of `workers` (backpressure).
    pub queue_factor: usize,
}

impl ValuationJob {
    pub fn new(k: usize) -> Self {
        ValuationJob {
            k,
            engine: Engine::Rust,
            block_size: 32,
            workers: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4),
            metric: Metric::SqEuclidean,
            queue_factor: 2,
        }
    }

    pub fn with_engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn with_block_size(mut self, block: usize) -> Self {
        self.block_size = block.max(1);
        self
    }

    /// Shard the test set into [lo, hi) block ranges.
    pub fn plan_shards(&self, n_test: usize) -> Vec<(usize, usize)> {
        assert!(n_test > 0, "empty test set");
        let b = self.block_size.max(1);
        (0..n_test.div_ceil(b))
            .map(|i| (i * b, ((i + 1) * b).min(n_test)))
            .collect()
    }
}

/// The outcome of a valuation job.
#[derive(Clone, Debug)]
pub struct ValuationResult {
    /// Averaged interaction matrix (Eq. 9), diagonal = main terms.
    pub phi: Matrix,
    /// Number of test points contributing.
    pub weight: f64,
    /// Blocks processed.
    pub blocks: usize,
    pub elapsed: Duration,
    /// Test points per second.
    pub throughput: f64,
    pub engine: Engine,
}

impl ValuationResult {
    /// Average interaction of the strict upper triangle (summary stat the
    /// examples print).
    pub fn mean_offdiag(&self) -> f64 {
        let ut = self.phi.upper_triangle_entries();
        crate::util::stats::mean(&ut)
    }
}

/// A unit of work: one test-block range of the dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    pub index: usize,
    pub lo: usize,
    pub hi: usize,
}

/// The partial result a worker produces for one shard.
pub struct PartialResult {
    pub index: usize,
    pub phi_sum: Matrix,
    pub weight: f64,
}

/// Helper: the shard list for a dataset under this job.
pub fn shards_for(job: &ValuationJob, ds: &Dataset) -> Vec<Shard> {
    job.plan_shards(ds.n_test())
        .into_iter()
        .enumerate()
        .map(|(index, (lo, hi))| Shard { index, lo, hi })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_cover_test_set_without_overlap() {
        let job = ValuationJob::new(3).with_block_size(8);
        for n_test in [1usize, 7, 8, 9, 64, 65] {
            let shards = job.plan_shards(n_test);
            assert_eq!(shards[0].0, 0);
            assert_eq!(shards.last().unwrap().1, n_test);
            for w in shards.windows(2) {
                assert_eq!(w[0].1, w[1].0, "gap/overlap at {w:?}");
            }
            assert!(shards.iter().all(|&(lo, hi)| hi - lo <= 8 && hi > lo));
        }
    }

    #[test]
    fn builder_clamps() {
        let job = ValuationJob::new(5).with_workers(0).with_block_size(0);
        assert_eq!(job.workers, 1);
        assert_eq!(job.block_size, 1);
    }

    #[test]
    #[should_panic(expected = "empty test set")]
    fn empty_test_set_panics() {
        ValuationJob::new(3).plan_shards(0);
    }
}
