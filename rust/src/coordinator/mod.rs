//! Layer-3 coordinator: the streaming data-valuation pipeline.
//!
//! A valuation job shards the test set into blocks, feeds them through a
//! bounded work queue (backpressure) to a pool of workers, and merges the
//! per-block partial sums deterministically (Eq. 9 linearity over the
//! test set makes the merge an exact weighted sum — results are
//! bit-identical regardless of worker count or arrival order because the
//! merger sums in block-index order).
//!
//! * [`pool`]    — thread pool + bounded channel substrate
//! * [`job`]     — job/result types and sharding plan
//! * [`merge`]   — deterministic partial-sum reduction
//! * [`pipeline`] — the orchestrator wiring it all together
//! * [`progress`] — atomic counters / throughput metrics

pub mod job;
pub mod merge;
pub mod pipeline;
pub mod pool;
pub mod progress;

pub use job::{ValuationJob, ValuationResult};
pub use pipeline::{run_job, run_job_with_engine};
