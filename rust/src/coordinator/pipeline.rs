//! The orchestrator: shard → bounded queue → worker pool → deterministic
//! merge.
//!
//! Engine dispatch:
//! * `Engine::Rust` — each worker runs the pure-Rust Algorithm 1 on its
//!   shard (scales linearly with cores; see benches/pipeline.rs).
//! * `Engine::Xla`  — each worker owns a [`StiExecutor`] compiled from the
//!   matching AOT artifact (one PJRT client per worker; the CPU plugin
//!   serializes execution per client, so per-worker clients are what
//!   gives real parallelism).

use super::job::{shards_for, PartialResult, Shard, ValuationJob, ValuationResult};
use super::merge::Merger;
use super::pool::{run_workers, Bounded};

use super::progress::{Progress, ThroughputMeter};
use crate::data::Dataset;
use crate::runtime::{executor_for, Engine, Manifest, StiExecutor};
use crate::shapley::sti_knn::{sti_knn_partial, StiParams};
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Mutex;

/// Run a valuation job with the pure-Rust engine (no artifacts needed).
pub fn run_job(ds: &Dataset, job: &ValuationJob) -> Result<ValuationResult> {
    anyhow::ensure!(job.engine == Engine::Rust, "use run_job_with_engine for XLA");
    run_rust(ds, job)
}

/// Run a valuation job with either engine; `artifacts_dir` is only read
/// for `Engine::Xla`.
pub fn run_job_with_engine(
    ds: &Dataset,
    job: &ValuationJob,
    artifacts_dir: &Path,
) -> Result<ValuationResult> {
    match job.engine {
        Engine::Rust => run_rust(ds, job),
        Engine::Xla => run_xla(ds, job, artifacts_dir),
    }
}

fn run_rust(ds: &Dataset, job: &ValuationJob) -> Result<ValuationResult> {
    let params = StiParams {
        k: job.k,
        metric: job.metric,
    };
    let meter = ThroughputMeter::new();
    let progress = Progress::new();
    let shards = shards_for(job, ds);
    let merger = Mutex::new(Merger::new(shards.len()));
    let queue: Bounded<Shard> = Bounded::new(job.workers * job.queue_factor.max(1));

    std::thread::scope(|s| {
        s.spawn(|| {
            for shard in &shards {
                if queue.send(*shard).is_err() {
                    break;
                }
            }
            queue.close();
        });
        run_workers(&queue, job.workers, |_w, shard: Shard| {
            let t0 = std::time::Instant::now();
            let (tx, ty) = ds.test_slice(shard.lo, shard.hi);
            let (phi_sum, weight) =
                sti_knn_partial(&ds.train_x, &ds.train_y, ds.d, tx, ty, &params);
            progress.record_block(shard.hi - shard.lo, t0.elapsed().as_nanos() as u64);
            merger.lock().unwrap().push(PartialResult {
                index: shard.index,
                phi_sum,
                weight,
            });
        });
    });

    let (phi, weight) = merger.into_inner().unwrap().finalize();
    let elapsed = meter.elapsed();
    Ok(ValuationResult {
        phi,
        weight,
        blocks: shards.len(),
        elapsed,
        throughput: meter.rate(progress.points()),
        engine: Engine::Rust,
    })
}

fn run_xla(ds: &Dataset, job: &ValuationJob, artifacts_dir: &Path) -> Result<ValuationResult> {
    let manifest = Manifest::load(artifacts_dir)?;
    // Bind the job to the artifact's baked block size.
    let spec = manifest
        .find("sti", ds.n_train(), ds.d, job.k)
        .with_context(|| {
            format!(
                "no sti artifact for (n={}, d={}, k={}); run `make artifacts` \
                 with this shape in DEFAULT_GRID or use --engine rust",
                ds.n_train(),
                ds.d,
                job.k
            )
        })?;
    let block = spec.b;
    let job = job.clone().with_block_size(block);

    let meter = ThroughputMeter::new();
    let progress = Progress::new();
    let shards = shards_for(&job, ds);
    let merger = Mutex::new(Merger::new(shards.len()));
    let queue: Bounded<Shard> = Bounded::new(job.workers * job.queue_factor.max(1));

    let errors: Mutex<Vec<anyhow::Error>> = Mutex::new(Vec::new());

    // The xla crate's PJRT handles are !Send (Rc internally), so each
    // worker thread constructs — and keeps — its own client + compiled
    // executable; only Shards and PartialResults cross thread boundaries.
    std::thread::scope(|s| {
        s.spawn(|| {
            for shard in &shards {
                if queue.send(*shard).is_err() {
                    break;
                }
            }
            queue.close();
        });
        for _w in 0..job.workers {
            let queue = &queue;
            let manifest = &manifest;
            let merger = &merger;
            let errors = &errors;
            let progress = &progress;
            let job = &job;
            s.spawn(move || {
                let exec: StiExecutor =
                    match executor_for(manifest, "sti", ds.n_train(), ds.d, job.k) {
                        Ok(e) => e,
                        Err(e) => {
                            errors.lock().unwrap().push(e);
                            queue.close();
                            return;
                        }
                    };
                while let Some(shard) = queue.recv() {
                    let t0 = std::time::Instant::now();
                    let (tx, ty) = ds.test_slice(shard.lo, shard.hi);
                    match exec.run_block(&ds.train_x, &ds.train_y, tx, ty) {
                        Ok((phi_sum, weight)) => {
                            progress.record_block(
                                shard.hi - shard.lo,
                                t0.elapsed().as_nanos() as u64,
                            );
                            merger.lock().unwrap().push(PartialResult {
                                index: shard.index,
                                phi_sum,
                                weight,
                            });
                        }
                        Err(e) => {
                            errors.lock().unwrap().push(e.context(format!(
                                "shard {} [{}, {})",
                                shard.index, shard.lo, shard.hi
                            )));
                            queue.close(); // fail fast: stop feeding workers
                        }
                    }
                }
            });
        }
    });

    let errs = errors.into_inner().unwrap();
    if let Some(e) = errs.into_iter().next() {
        return Err(e);
    }
    let (phi, weight) = merger.into_inner().unwrap().finalize();
    let elapsed = meter.elapsed();
    Ok(ValuationResult {
        phi,
        weight,
        blocks: shards.len(),
        elapsed,
        throughput: meter.rate(progress.points()),
        engine: Engine::Xla,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::load_dataset;
    use crate::shapley::sti_knn::sti_knn;

    #[test]
    fn pipeline_equals_single_threaded_reference() {
        let ds = load_dataset("moon", 60, 23, 5).unwrap();
        let reference = sti_knn(
            &ds.train_x,
            &ds.train_y,
            ds.d,
            &ds.test_x,
            &ds.test_y,
            &StiParams::new(5),
        );
        for workers in [1usize, 2, 4] {
            for block in [1usize, 7, 16, 64] {
                let job = ValuationJob::new(5)
                    .with_workers(workers)
                    .with_block_size(block);
                let res = run_job(&ds, &job).unwrap();
                assert_eq!(res.weight, 23.0);
                assert!(
                    res.phi.max_abs_diff(&reference) < 1e-12,
                    "workers={workers} block={block}"
                );
            }
        }
    }

    #[test]
    fn pipeline_bit_deterministic_across_worker_counts() {
        let ds = load_dataset("click", 80, 17, 9).unwrap();
        let run = |workers| {
            let job = ValuationJob::new(3).with_workers(workers).with_block_size(4);
            run_job(&ds, &job).unwrap().phi
        };
        let a = run(1);
        let b = run(3);
        let c = run(8);
        // bitwise equality, not approximate
        assert_eq!(a.data().len(), b.data().len());
        for i in 0..a.data().len() {
            assert_eq!(a.data()[i].to_bits(), b.data()[i].to_bits());
            assert_eq!(b.data()[i].to_bits(), c.data()[i].to_bits());
        }
    }

    #[test]
    fn throughput_and_blocks_reported() {
        let ds = load_dataset("cpu", 50, 10, 2).unwrap();
        let job = ValuationJob::new(3).with_workers(2).with_block_size(3);
        let res = run_job(&ds, &job).unwrap();
        assert_eq!(res.blocks, 4); // ceil(10/3)
        assert!(res.throughput > 0.0);
        assert!(res.elapsed.as_nanos() > 0);
    }
}
