//! Minimal CSV I/O: export interaction matrices / value vectors for
//! external plotting, and load labeled feature tables (numeric features,
//! last column = integer class label).

use crate::util::matrix::Matrix;
use std::io::{BufRead, Write};
use std::path::Path;

/// Write a matrix as CSV (no header).
pub fn write_matrix(path: &Path, m: &Matrix) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for i in 0..m.rows() {
        let row: Vec<String> = m.row(i).iter().map(|v| format!("{v:.9e}")).collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Write (index, value) rows with a header.
pub fn write_values(path: &Path, header: &str, values: &[f64]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "index,{header}")?;
    for (i, v) in values.iter().enumerate() {
        writeln!(f, "{i},{v:.9e}")?;
    }
    Ok(())
}

/// Read a numeric CSV with the last column as integer label.
/// Returns (features row-major, labels, d). Skips a header row if the
/// first field of the first line is not numeric.
pub fn read_labeled(path: &Path) -> std::io::Result<(Vec<f32>, Vec<i32>, usize)> {
    let f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut xs: Vec<f32> = Vec::new();
    let mut ys: Vec<i32> = Vec::new();
    let mut d = 0usize;
    for (lineno, line) in f.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        if fields.len() < 2 {
            return Err(bad(lineno, "need at least one feature and a label"));
        }
        if lineno == 0 && fields[0].trim().parse::<f64>().is_err() {
            continue; // header
        }
        let row_d = fields.len() - 1;
        if d == 0 {
            d = row_d;
        } else if row_d != d {
            return Err(bad(lineno, "inconsistent column count"));
        }
        for v in &fields[..row_d] {
            xs.push(
                v.trim()
                    .parse::<f32>()
                    .map_err(|e| bad(lineno, &format!("feature: {e}")))?,
            );
        }
        ys.push(
            fields[row_d]
                .trim()
                .parse::<f32>()
                .map_err(|e| bad(lineno, &format!("label: {e}")))? as i32,
        );
    }
    Ok((xs, ys, d))
}

fn bad(lineno: usize, msg: &str) -> std::io::Error {
    std::io::Error::new(
        std::io::ErrorKind::InvalidData,
        format!("csv line {}: {msg}", lineno + 1),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("stiknn_csv_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn matrix_roundtrips_via_read_labeled_shape() {
        let p = tmp("m.csv");
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        write_matrix(&p, &m).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.starts_with("1.000000000e0,"));
    }

    #[test]
    fn values_file_has_header() {
        let p = tmp("v.csv");
        write_values(&p, "shapley", &[0.5, -0.25]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next(), Some("index,shapley"));
        assert_eq!(lines.count(), 2);
    }

    #[test]
    fn read_labeled_with_header_and_without() {
        let p = tmp("d.csv");
        std::fs::write(&p, "x1,x2,label\n1.0,2.0,0\n3.0,4.0,1\n").unwrap();
        let (xs, ys, d) = read_labeled(&p).unwrap();
        assert_eq!((xs, ys, d), (vec![1.0, 2.0, 3.0, 4.0], vec![0, 1], 2));

        std::fs::write(&p, "1.5,0\n2.5,1\n").unwrap();
        let (xs, ys, d) = read_labeled(&p).unwrap();
        assert_eq!((xs, ys, d), (vec![1.5, 2.5], vec![0, 1], 1));
    }

    #[test]
    fn read_labeled_rejects_ragged_rows() {
        let p = tmp("bad.csv");
        std::fs::write(&p, "1.0,2.0,0\n3.0,1\n").unwrap();
        assert!(read_labeled(&p).is_err());
    }
}
