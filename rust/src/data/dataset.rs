//! The dataset container shared by every engine and the coordinator.

/// A labeled dataset split into train and test parts. Features are
/// row-major f32 (the dtype of the XLA artifacts); labels are i32 class
/// ids 0..classes.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub d: usize,
    pub classes: usize,
    pub train_x: Vec<f32>,
    pub train_y: Vec<i32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
}

impl Dataset {
    pub fn n_train(&self) -> usize {
        self.train_y.len()
    }

    pub fn n_test(&self) -> usize {
        self.test_y.len()
    }

    /// Panics if any internal invariant is broken (shape mismatches,
    /// out-of-range labels). Called by generators and loaders.
    pub fn validate(&self) {
        assert_eq!(
            self.train_x.len(),
            self.train_y.len() * self.d,
            "{}: train shape",
            self.name
        );
        assert_eq!(
            self.test_x.len(),
            self.test_y.len() * self.d,
            "{}: test shape",
            self.name
        );
        assert!(self.classes >= 2, "{}: needs >= 2 classes", self.name);
        for &y in self.train_y.iter().chain(&self.test_y) {
            assert!(
                (0..self.classes as i32).contains(&y),
                "{}: label {y} out of range",
                self.name
            );
        }
        assert!(
            self.train_x.iter().chain(&self.test_x).all(|v| v.is_finite()),
            "{}: non-finite feature",
            self.name
        );
    }

    /// The i-th training feature row.
    pub fn train_row(&self, i: usize) -> &[f32] {
        &self.train_x[i * self.d..(i + 1) * self.d]
    }

    /// The p-th test feature row.
    pub fn test_row(&self, p: usize) -> &[f32] {
        &self.test_x[p * self.d..(p + 1) * self.d]
    }

    /// Per-class counts over the training labels.
    pub fn train_class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.classes];
        for &y in &self.train_y {
            counts[y as usize] += 1;
        }
        counts
    }

    /// A copy restricted to `test_range` of the test set (coordinator
    /// sharding helper; train part is shared by clone).
    pub fn test_slice(&self, lo: usize, hi: usize) -> (&[f32], &[i32]) {
        (&self.test_x[lo * self.d..hi * self.d], &self.test_y[lo..hi])
    }

    /// Keep only the selected training indices (used by the
    /// summarization/removal experiments). Preserves order.
    pub fn retain_train(&self, keep: &[usize]) -> Dataset {
        let mut out = self.clone();
        out.train_x = Vec::with_capacity(keep.len() * self.d);
        out.train_y = Vec::with_capacity(keep.len());
        for &i in keep {
            out.train_x.extend_from_slice(self.train_row(i));
            out.train_y.push(self.train_y[i]);
        }
        out.name = format!("{}[{} kept]", self.name, keep.len());
        out
    }

    /// Paper's matrix ordering (§4): indices sorted by class, then by
    /// feature 0, then feature 1... Returns the permutation to apply to
    /// train indices before rendering interaction heatmaps.
    pub fn paper_display_order(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.n_train()).collect();
        idx.sort_by(|&a, &b| {
            self.train_y[a].cmp(&self.train_y[b]).then_with(|| {
                let ra = self.train_row(a);
                let rb = self.train_row(b);
                for (x, y) in ra.iter().zip(rb) {
                    match x.partial_cmp(y) {
                        Some(std::cmp::Ordering::Equal) | None => continue,
                        Some(o) => return o,
                    }
                }
                std::cmp::Ordering::Equal
            })
        });
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            name: "tiny".into(),
            d: 2,
            classes: 2,
            train_x: vec![0.0, 0.0, 1.0, 0.0, 0.5, 1.0],
            train_y: vec![0, 1, 0],
            test_x: vec![0.1, 0.1],
            test_y: vec![0],
        }
    }

    #[test]
    fn validate_accepts_consistent() {
        tiny().validate();
    }

    #[test]
    #[should_panic(expected = "label")]
    fn validate_rejects_bad_label() {
        let mut ds = tiny();
        ds.train_y[0] = 7;
        ds.validate();
    }

    #[test]
    fn rows_and_counts() {
        let ds = tiny();
        assert_eq!(ds.train_row(1), &[1.0, 0.0]);
        assert_eq!(ds.test_row(0), &[0.1, 0.1]);
        assert_eq!(ds.train_class_counts(), vec![2, 1]);
    }

    #[test]
    fn retain_train_keeps_selection_in_order() {
        let ds = tiny();
        let sub = ds.retain_train(&[2, 0]);
        assert_eq!(sub.train_y, vec![0, 0]);
        assert_eq!(sub.train_row(0), &[0.5, 1.0]);
        sub.validate();
    }

    #[test]
    fn paper_display_order_sorts_class_then_features() {
        let ds = Dataset {
            name: "o".into(),
            d: 1,
            classes: 2,
            train_x: vec![5.0, 1.0, 3.0, 2.0],
            train_y: vec![1, 0, 0, 1],
            test_x: vec![],
            test_y: vec![],
        };
        // class 0: indices 1 (x=1), 2 (x=3); class 1: 3 (x=2), 0 (x=5)
        assert_eq!(ds.paper_display_order(), vec![1, 2, 3, 0]);
    }

    #[test]
    fn test_slice_views() {
        let ds = Dataset {
            name: "s".into(),
            d: 2,
            classes: 2,
            train_x: vec![0.0; 4],
            train_y: vec![0, 1],
            test_x: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            test_y: vec![0, 1, 0],
        };
        let (x, y) = ds.test_slice(1, 3);
        assert_eq!(x, &[3.0, 4.0, 5.0, 6.0]);
        assert_eq!(y, &[1, 0]);
    }
}
