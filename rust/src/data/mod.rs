//! Dataset substrate: containers, synthetic generators (including twins of
//! every dataset in the paper's Table 1 — see DESIGN.md §5 for the
//! substitution rationale), splits, corruption (mislabeling/redundancy for
//! Figs. 4–5), and CSV I/O.

pub mod corrupt;
pub mod csv;
pub mod dataset;
pub mod registry;
pub mod split;
pub mod synth;

pub use dataset::Dataset;
pub use registry::{load_dataset, registry_names, DatasetSpec};
