//! Reporting: ASCII heatmaps (the terminal stand-in for the paper's
//! matplotlib figures), aligned tables, and experiment-record helpers.

pub mod heatmap;
pub mod table;

pub use heatmap::render_heatmap;
pub use table::Table;
