//! Reporting: ASCII heatmaps (the terminal stand-in for the paper's
//! matplotlib figures), aligned tables, experiment-record helpers, and
//! session snapshot/top-k formatting.

pub mod heatmap;
pub mod session;
pub mod table;

pub use heatmap::render_heatmap;
pub use table::Table;
