//! Session-layer report formatting: snapshot headers and top-k
//! point-value tables for the `stiknn session` inspector (DESIGN.md §9).

use crate::report::table::Table;
use crate::session::SnapshotHeader;

/// Human-readable header table for one decoded snapshot.
pub fn snapshot_info_table(h: &SnapshotHeader) -> String {
    let mut t = Table::new(&["field", "value"]);
    t.row(&["format version".into(), h.version.to_string()]);
    t.row(&["k".into(), h.k.to_string()]);
    t.row(&["metric".into(), format!("{:?}", h.metric)]);
    t.row(&["engine".into(), h.engine.label().to_string()]);
    t.row(&["n (train points)".into(), h.n.to_string()]);
    t.row(&["d (features)".into(), h.d.to_string()]);
    t.row(&["tests ingested".into(), h.tests.to_string()]);
    t.row(&["ledger entries".into(), h.batches.to_string()]);
    t.row(&["train fingerprint".into(), format!("{:016x}", h.fingerprint)]);
    format!("session snapshot:\n{}", t.render())
}

/// Ranked top-k point values as an aligned table.
pub fn topk_table(entries: &[(usize, f64)], by: &str) -> String {
    let mut t = Table::new(&["rank", "train index", "value"]);
    for (rank, &(index, value)) in entries.iter().enumerate() {
        t.row(&[
            (rank + 1).to_string(),
            index.to_string(),
            format!("{value:+.4e}"),
        ]);
    }
    format!("top-{} point values (by {by}):\n{}", entries.len(), t.render())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knn::distance::Metric;

    #[test]
    fn snapshot_table_lists_all_fields() {
        let h = SnapshotHeader {
            version: 2,
            k: 5,
            metric: Metric::SqEuclidean,
            engine: crate::session::Engine::Implicit,
            n: 600,
            d: 2,
            fingerprint: 0xABCD,
            tests: 150,
            batches: 3,
        };
        let s = snapshot_info_table(&h);
        for needle in [
            "version", "SqEuclidean", "implicit", "600", "150", "000000000000abcd",
        ] {
            assert!(s.contains(needle), "missing {needle}:\n{s}");
        }
    }

    #[test]
    fn topk_table_ranks_from_one() {
        let s = topk_table(&[(7, 0.25), (2, -0.5)], "main");
        assert!(s.contains("top-2"));
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[3].starts_with('1'), "{s}");
        assert!(s.contains("+2.5000e-1") || s.contains("+2.5000e1"), "{s}");
    }
}
