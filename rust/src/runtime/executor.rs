//! Compiled-executable cache and typed execution of the AOT artifacts.
//!
//! [`StiExecutor`] binds a PJRT CPU client to one `sti` (or `knn_shapley`)
//! artifact: it marshals f32/i32 slices into XLA literals, pads the test
//! block to the artifact's baked size `b` (padding rows have mask 0 and
//! contribute nothing — the L2 program multiplies every per-test matrix by
//! its mask entry), executes, and unmarshals the partial sums.
//!
//! Compilation happens once per artifact (at construction); execution is
//! allocation-light and thread-safe behind `&self` (the PJRT client
//! serializes execution internally; the coordinator runs one executor per
//! worker when it wants real parallelism).

use super::artifact::{ArtifactSpec, Manifest};
use crate::util::matrix::Matrix;
use anyhow::{bail, Context, Result};

/// Which computation backend a valuation job uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Pure-Rust Algorithm 1 (any shape).
    Rust,
    /// AOT XLA artifact via PJRT (shape must match an artifact).
    Xla,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "rust" => Some(Engine::Rust),
            "xla" => Some(Engine::Xla),
            _ => None,
        }
    }
}

/// A compiled STI (or KNN-Shapley) block program bound to fixed shapes.
pub struct StiExecutor {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl StiExecutor {
    /// Compile the artifact on a fresh PJRT CPU client.
    pub fn new(manifest: &Manifest, spec: &ArtifactSpec) -> Result<StiExecutor> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Self::with_client(&client, manifest, spec)
    }

    /// Compile the artifact on an existing client (one client can host
    /// many executables).
    pub fn with_client(
        client: &xla::PjRtClient,
        manifest: &Manifest,
        spec: &ArtifactSpec,
    ) -> Result<StiExecutor> {
        let path = manifest.path_of(spec);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.name))?;
        Ok(StiExecutor {
            spec: spec.clone(),
            exe,
        })
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute on one test block of size ≤ b. Returns the UNNORMALIZED
    /// (phi_sum, weight) pair for `sti` artifacts, where phi_sum is n×n.
    /// For `knn_shapley` artifacts use [`Self::run_values_block`].
    pub fn run_block(
        &self,
        train_x: &[f32],
        train_y: &[i32],
        test_x: &[f32],
        test_y: &[i32],
    ) -> Result<(Matrix, f64)> {
        if self.spec.program != "sti" {
            bail!("run_block on a {} artifact", self.spec.program);
        }
        let outs = self.execute_padded(train_x, train_y, test_x, test_y)?;
        let (phi_lit, w_lit) = (outs.0, outs.1);
        let n = self.spec.n;
        let phi_f32 = phi_lit.to_vec::<f32>().context("phi_sum to_vec")?;
        if phi_f32.len() != n * n {
            bail!("phi_sum has {} entries, expected {}", phi_f32.len(), n * n);
        }
        let phi = Matrix::from_vec(n, n, phi_f32.into_iter().map(|v| v as f64).collect());
        let w = w_lit.to_vec::<f32>().context("weight to_vec")?[0] as f64;
        Ok((phi, w))
    }

    /// Execute a `knn_shapley` artifact block: returns (s_sum, weight).
    pub fn run_values_block(
        &self,
        train_x: &[f32],
        train_y: &[i32],
        test_x: &[f32],
        test_y: &[i32],
    ) -> Result<(Vec<f64>, f64)> {
        if self.spec.program != "knn_shapley" {
            bail!("run_values_block on a {} artifact", self.spec.program);
        }
        let outs = self.execute_padded(train_x, train_y, test_x, test_y)?;
        let s = outs
            .0
            .to_vec::<f32>()
            .context("s_sum to_vec")?
            .into_iter()
            .map(|v| v as f64)
            .collect();
        let w = outs.1.to_vec::<f32>().context("weight to_vec")?[0] as f64;
        Ok((s, w))
    }

    fn execute_padded(
        &self,
        train_x: &[f32],
        train_y: &[i32],
        test_x: &[f32],
        test_y: &[i32],
    ) -> Result<(xla::Literal, xla::Literal)> {
        let (n, d, b) = (self.spec.n, self.spec.d, self.spec.b);
        if train_y.len() != n || train_x.len() != n * d {
            bail!(
                "train shape ({}, {}) does not match artifact {} (n={n}, d={d})",
                train_y.len(),
                train_x.len(),
                self.spec.name
            );
        }
        let t = test_y.len();
        if t == 0 || t > b {
            bail!("test block size {t} out of range 1..={b}");
        }
        if test_x.len() != t * d {
            bail!("test_x len {} != t*d = {}", test_x.len(), t * d);
        }
        // pad test block to b with mask 0 (padded features replicate row 0
        // so distances stay finite)
        let mut px = Vec::with_capacity(b * d);
        px.extend_from_slice(test_x);
        let mut py = Vec::with_capacity(b);
        py.extend_from_slice(test_y);
        let mut mask = vec![1.0f32; t];
        for _ in t..b {
            px.extend_from_slice(&test_x[..d]);
            py.push(test_y[0]);
            mask.push(0.0);
        }

        let lit_train_x = xla::Literal::vec1(train_x).reshape(&[n as i64, d as i64])?;
        let lit_train_y = xla::Literal::vec1(train_y);
        let lit_test_x = xla::Literal::vec1(&px).reshape(&[b as i64, d as i64])?;
        let lit_test_y = xla::Literal::vec1(&py);
        let lit_mask = xla::Literal::vec1(&mask);

        let result = self
            .exe
            .execute::<xla::Literal>(&[lit_train_x, lit_train_y, lit_test_x, lit_test_y, lit_mask])
            .context("PJRT execute")?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (phi_sum, weight)
        Ok(result.to_tuple2()?)
    }
}

/// Convenience: find + compile the right artifact for a dataset shape.
pub fn executor_for(
    manifest: &Manifest,
    program: &str,
    n: usize,
    d: usize,
    k: usize,
) -> Result<StiExecutor> {
    let spec = manifest.find(program, n, d, k).with_context(|| {
        let available: Vec<String> = manifest
            .of_program(program)
            .iter()
            .map(|a| format!("(n={}, d={}, b={}, k={})", a.n, a.d, a.b, a.k))
            .collect();
        format!(
            "no '{program}' artifact for (n={n}, d={d}, k={k}); available: {} — \
             add the shape to python/compile/aot.py DEFAULT_GRID and re-run \
             `make artifacts`, or use --engine rust",
            available.join(", ")
        )
    })?;
    StiExecutor::new(manifest, spec)
}
