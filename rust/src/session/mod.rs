//! Incremental valuation sessions — the long-lived layer that turns the
//! one-shot pipeline into a service (DESIGN.md §9).
//!
//! Eq. 9 makes the interaction matrix a weighted average over test
//! points: Φ = (1/t)·Σ_τ Φ_τ. The sum is exactly additive under
//! streaming test arrivals, so a deployment never has to recompute from
//! scratch when new evaluation data lands. A [`ValuationSession`] owns
//! the UNNORMALIZED n×n accumulator plus a per-batch weight ledger,
//! ingests test batches through the existing two-phase hot path
//! ([`crate::shapley::sti_knn_accumulate`] single-threaded, or the
//! coordinator's banded prep pool via [`crate::coordinator::ingest_banded`]
//! for large batches), and answers queries against the live matrix at any
//! time — normalization happens at read time, so ingest stays O(t·n²)
//! total with no per-query rescaling of state.
//!
//! Exactness: every accumulator cell receives its per-test additions in
//! test order no matter how the stream is cut into batches, so ingesting
//! any contiguous partition of a test set — including a snapshot/restore
//! cycle mid-stream ([`store`]) — is **bit-identical** to one-shot
//! `sti_knn` (property-tested in `tests/session_equivalence.rs`).
//! Re-ordering batches changes addition order and is therefore only
//! equal up to f64 associativity (~1e-12), not bitwise.
//!
//! * [`store`]    — versioned, checksummed binary snapshots
//! * [`protocol`] — NDJSON command loop backing `stiknn serve`

pub mod protocol;
pub mod store;

pub use store::{dataset_fingerprint, Snapshot, SnapshotHeader};

use crate::coordinator::{ingest_banded, ValuationJob};
use crate::data::Dataset;
use crate::knn::distance::Metric;
use crate::shapley::sti_knn::{sti_knn_accumulate, StiParams};
use crate::util::matrix::Matrix;
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// Ranking used by top-k point-value queries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopBy {
    /// Diagonal main terms φ_ii (Eq. 4/5) — each point's own effect.
    Main,
    /// φ_ii + Σ_{j≠i} φ_ij — main effect plus all pairwise interactions,
    /// the "total contribution including synergies" view.
    RowSum,
}

impl TopBy {
    pub fn parse(s: &str) -> Option<TopBy> {
        match s {
            "main" | "diag" => Some(TopBy::Main),
            "rowsum" | "total" => Some(TopBy::RowSum),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            TopBy::Main => "main",
            TopBy::RowSum => "rowsum",
        }
    }
}

/// Session tuning knobs (the valuation semantics are fixed by k/metric;
/// everything else is pure performance).
#[derive(Clone, Copy, Debug)]
pub struct SessionConfig {
    pub k: usize,
    pub metric: Metric,
    /// Worker threads for the parallel ingest path (prep pool + bands).
    pub workers: usize,
    /// Test points per prep block in the parallel ingest path.
    pub block_size: usize,
    /// Batches with at least this many test points go through the
    /// coordinator's banded prep pool; smaller ones take the
    /// single-threaded hot path (thread spin-up would dominate). Either
    /// path produces identical bits, so this is a pure perf knob.
    pub parallel_min: usize,
}

impl SessionConfig {
    pub fn new(k: usize) -> Self {
        SessionConfig {
            k,
            metric: Metric::SqEuclidean,
            workers: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4),
            block_size: 32,
            parallel_min: 256,
        }
    }

    pub fn with_metric(mut self, metric: Metric) -> Self {
        self.metric = metric;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn with_block_size(mut self, block: usize) -> Self {
        self.block_size = block.max(1);
        self
    }

    pub fn with_parallel_min(mut self, parallel_min: usize) -> Self {
        self.parallel_min = parallel_min.max(1);
        self
    }
}

/// One entry of the per-batch weight ledger: `seq` is the monotone batch
/// sequence number, `len` the test count the entry accounts for (its
/// Eq. 9 merge weight). The ledger is persisted in snapshots, so a
/// restored session continues its sequence instead of restarting at 0.
///
/// The ledger is COMPACTED once it exceeds [`LEDGER_COMPACT_AT`] entries
/// (oldest half folded into one record that keeps the first `seq` and
/// sums the lens), so a long-lived serve deployment ingesting millions
/// of small batches holds O(1) ledger state and snapshot overhead. After
/// compaction an entry may therefore cover MANY ingests — `seq` (not the
/// entry count) is what tracks how many batches a session has seen
/// ([`ValuationSession::batches_ingested`]), and Σ len == tests stays an
/// integrity invariant the store verifies on decode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchRecord {
    pub seq: u64,
    pub len: u64,
}

/// Ledger length that triggers compaction of the oldest half.
pub const LEDGER_COMPACT_AT: usize = 4096;

/// Summary statistics over the live (averaged) matrix.
#[derive(Clone, Copy, Debug)]
pub struct SessionStats {
    pub n: usize,
    pub k: usize,
    pub tests: u64,
    pub batches: u64,
    /// Σ φ_ii of the averaged matrix (0 while no tests are ingested).
    pub trace: f64,
    /// Mean strict-upper-triangle entry of the averaged matrix.
    pub mean_offdiag: f64,
    /// Upper triangle including the diagonal — the efficiency-axiom
    /// quantity (DESIGN.md §1).
    pub upper_sum: f64,
}

/// A long-lived incremental valuation: train set + accumulator + ledger.
pub struct ValuationSession {
    train_x: Vec<f32>,
    train_y: Vec<i32>,
    d: usize,
    config: SessionConfig,
    /// Unnormalized Σ_τ Φ_τ, upper triangle + diagonal only (exactly the
    /// layout `sweep_band` writes); mirrored + scaled at query time.
    acc: Matrix,
    ledger: Vec<BatchRecord>,
    tests_seen: u64,
    fingerprint: u64,
}

impl ValuationSession {
    /// Fresh session over an owned train set. Fails on shape mismatches
    /// or a k outside Algorithm 1's exact domain 1 ≤ k ≤ n.
    pub fn new(
        train_x: Vec<f32>,
        train_y: Vec<i32>,
        d: usize,
        config: SessionConfig,
    ) -> Result<Self> {
        let n = train_y.len();
        ensure!(n >= 2, "need at least 2 training points for interactions");
        ensure!(d >= 1, "need at least 1 feature dimension");
        ensure!(
            train_x.len() == n * d,
            "train shape mismatch: {} features for {} points (d={d})",
            train_x.len(),
            n
        );
        ensure!(
            config.k >= 1 && config.k <= n,
            "STI-KNN is exact only for 1 <= k <= n (k={}, n={n})",
            config.k
        );
        let fingerprint = dataset_fingerprint(&train_x, &train_y, d);
        Ok(ValuationSession {
            train_x,
            train_y,
            d,
            config,
            acc: Matrix::zeros(n, n),
            ledger: Vec::new(),
            tests_seen: 0,
            fingerprint,
        })
    }

    /// Fresh session over a registry dataset's train part.
    pub fn from_dataset(ds: &Dataset, config: SessionConfig) -> Result<Self> {
        Self::new(ds.train_x.clone(), ds.train_y.clone(), ds.d, config)
    }

    /// Resume from a snapshot. The caller supplies the SAME train set the
    /// snapshot was taken against (sessions don't persist training data);
    /// k, metric, n, d and the train-set fingerprint are all verified, so
    /// a mismatched resume fails loudly instead of silently producing
    /// wrong values.
    pub fn restore(
        path: &Path,
        train_x: Vec<f32>,
        train_y: Vec<i32>,
        d: usize,
        config: SessionConfig,
    ) -> Result<Self> {
        let snap = store::read_snapshot(path)?;
        let mut session = Self::new(train_x, train_y, d, config)?;
        let h = &snap.header;
        ensure!(
            h.k as usize == session.config.k,
            "snapshot was taken with k={} but the session is configured with k={}",
            h.k,
            session.config.k
        );
        ensure!(
            h.metric == session.config.metric,
            "snapshot metric {:?} != session metric {:?}",
            h.metric,
            session.config.metric
        );
        ensure!(
            h.n as usize == session.n() && h.d as usize == session.d,
            "snapshot train shape (n={}, d={}) != session train shape (n={}, d={})",
            h.n,
            h.d,
            session.n(),
            session.d
        );
        ensure!(
            h.fingerprint == session.fingerprint,
            "snapshot fingerprint {:016x} != train-set fingerprint {:016x}: \
             the snapshot was taken against different training data",
            h.fingerprint,
            session.fingerprint
        );
        session.acc = snap.raw;
        session.tests_seen = h.tests;
        session.ledger = snap.ledger;
        Ok(session)
    }

    // -- identity ------------------------------------------------------

    pub fn n(&self) -> usize {
        self.train_y.len()
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn k(&self) -> usize {
        self.config.k
    }

    pub fn tests_seen(&self) -> u64 {
        self.tests_seen
    }

    pub fn ledger(&self) -> &[BatchRecord] {
        &self.ledger
    }

    /// Total ingest calls over the session's lifetime (including before
    /// a restore). Derived from the monotone batch sequence, so it
    /// survives ledger compaction — `ledger().len()` does not.
    pub fn batches_ingested(&self) -> u64 {
        self.ledger.last().map(|b| b.seq + 1).unwrap_or(0)
    }

    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    fn params(&self) -> StiParams {
        StiParams {
            k: self.config.k,
            metric: self.config.metric,
        }
    }

    // -- ingest --------------------------------------------------------

    /// Ingest one test batch (flattened row-major features + labels) and
    /// return its test count. Empty batches are a no-op. Batches of at
    /// least `config.parallel_min` points run through the coordinator's
    /// banded prep pool; both paths append the same additions in the same
    /// order, so the choice never changes a single bit of the state.
    pub fn ingest(&mut self, test_x: &[f32], test_y: &[i32]) -> Result<usize> {
        ensure!(
            test_x.len() == test_y.len() * self.d,
            "test batch shape mismatch: {} features for {} labels (d={})",
            test_x.len(),
            test_y.len(),
            self.d
        );
        if test_y.is_empty() {
            return Ok(0);
        }
        if test_y.len() >= self.config.parallel_min {
            let mut job = ValuationJob::new(self.config.k)
                .with_workers(self.config.workers)
                .with_block_size(self.config.block_size);
            job.metric = self.config.metric;
            ingest_banded(
                &self.train_x,
                &self.train_y,
                self.d,
                test_x,
                test_y,
                &job,
                &mut self.acc,
            )?;
        } else {
            sti_knn_accumulate(
                &self.train_x,
                &self.train_y,
                self.d,
                test_x,
                test_y,
                &self.params(),
                &mut self.acc,
            );
        }
        let seq = self.ledger.last().map(|b| b.seq + 1).unwrap_or(0);
        self.ledger.push(BatchRecord {
            seq,
            len: test_y.len() as u64,
        });
        if self.ledger.len() >= LEDGER_COMPACT_AT {
            // Fold the oldest half into one record (first seq, summed
            // lens): bounds ledger memory and snapshot size for
            // long-lived sessions while preserving Σ len == tests and
            // the monotone seq that batches_ingested() derives from.
            let half = self.ledger.len() / 2;
            let merged = BatchRecord {
                seq: self.ledger[0].seq,
                len: self.ledger[..half].iter().map(|b| b.len).sum(),
            };
            self.ledger.splice(..half, [merged]);
        }
        self.tests_seen += test_y.len() as u64;
        Ok(test_y.len())
    }

    // -- queries (all normalize at read time) --------------------------

    /// 1/t — the read-time normalization factor. `None` while empty.
    fn inv_weight(&self) -> Option<f64> {
        if self.tests_seen == 0 {
            None
        } else {
            Some(1.0 / self.tests_seen as f64)
        }
    }

    /// Averaged φ_ij (symmetric — (i,j) and (j,i) agree). `None` while
    /// the session is empty or an index is out of range.
    pub fn cell(&self, i: usize, j: usize) -> Option<f64> {
        let inv_w = self.inv_weight()?;
        if i >= self.n() || j >= self.n() {
            return None;
        }
        let (lo, hi) = if i <= j { (i, j) } else { (j, i) };
        Some(self.acc.get(lo, hi) * inv_w)
    }

    /// Averaged row i of the symmetric matrix (diagonal included).
    pub fn row(&self, i: usize) -> Option<Vec<f64>> {
        let inv_w = self.inv_weight()?;
        if i >= self.n() {
            return None;
        }
        Some(
            (0..self.n())
                .map(|j| {
                    let (lo, hi) = if i <= j { (i, j) } else { (j, i) };
                    self.acc.get(lo, hi) * inv_w
                })
                .collect(),
        )
    }

    /// The full averaged interaction matrix — exactly what one-shot
    /// `sti_knn` over every ingested test point would return, to the bit
    /// (same accumulator, same mirror-then-scale finalization).
    pub fn matrix(&self) -> Option<Matrix> {
        let inv_w = self.inv_weight()?;
        let mut m = self.acc.clone();
        m.mirror_upper_to_lower();
        m.scale(inv_w);
        Some(m)
    }

    /// Per-point values under the given ranking.
    pub fn point_values(&self, by: TopBy) -> Option<Vec<f64>> {
        let inv_w = self.inv_weight()?;
        Some(point_values_raw(&self.acc, inv_w, by))
    }

    /// Top-k (index, value), descending; ties break by index.
    pub fn top_k(&self, k: usize, by: TopBy) -> Option<Vec<(usize, f64)>> {
        Some(top_k_of(&self.point_values(by)?, k))
    }

    /// Summary statistics (zeros while the session is empty). One O(n²)
    /// triangle walk + one O(n) diagonal pass — this runs per `stats`
    /// protocol command on live sessions, so no redundant passes.
    pub fn stats(&self) -> SessionStats {
        let n = self.n();
        let inv_w = self.inv_weight().unwrap_or(0.0);
        let pairs = (n * (n - 1) / 2) as f64;
        let upper = self.acc.upper_triangle_sum();
        let trace_raw: f64 = self.acc.diagonal().iter().sum();
        SessionStats {
            n,
            k: self.config.k,
            tests: self.tests_seen,
            batches: self.batches_ingested(),
            trace: trace_raw * inv_w,
            mean_offdiag: if pairs > 0.0 {
                (upper - trace_raw) * inv_w / pairs
            } else {
                0.0
            },
            upper_sum: upper * inv_w,
        }
    }

    // -- persistence ---------------------------------------------------

    /// Write a snapshot (see [`store`] for the format). Returns the byte
    /// count written.
    ///
    /// The write is atomic-by-rename (temp sibling file, then rename
    /// over the target): deployments snapshot to the SAME path on a
    /// schedule, and a crash or full disk mid-write must never destroy
    /// the previous good snapshot.
    pub fn save(&self, path: &Path) -> Result<u64> {
        let bytes = store::encode(
            self.config.k as u32,
            self.config.metric,
            self.n() as u64,
            self.d as u64,
            self.fingerprint,
            self.tests_seen,
            &self.ledger,
            self.acc.data(),
        );
        // PID-unique temp sibling: two processes snapshotting the same
        // target must not interleave writes into one temp file.
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(format!(".{}.tmp", std::process::id()));
        let tmp = std::path::PathBuf::from(tmp_name);
        let written = (|| -> std::io::Result<()> {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            // Flush data blocks to disk BEFORE the rename becomes
            // visible: rename-without-fsync can survive a crash while
            // the data doesn't, leaving a truncated file at the target.
            f.sync_all()
        })();
        if let Err(e) = written {
            let _ = std::fs::remove_file(&tmp);
            return Err(e)
                .with_context(|| format!("writing snapshot temp file {}", tmp.display()));
        }
        if let Err(e) = std::fs::rename(&tmp, path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e)
                .with_context(|| format!("renaming snapshot into place at {}", path.display()));
        }
        Ok(bytes.len() as u64)
    }
}

/// Per-point values from a RAW accumulator (upper triangle + diagonal)
/// and a normalization factor — shared by live sessions and decoded
/// snapshots. RowSum expands the symmetric row without materializing the
/// mirror: row i = φ_ii + Σ_{j>i} acc[i][j] + Σ_{j<i} acc[j][i].
pub(crate) fn point_values_raw(acc: &Matrix, inv_w: f64, by: TopBy) -> Vec<f64> {
    let n = acc.rows();
    match by {
        TopBy::Main => (0..n).map(|i| acc.get(i, i) * inv_w).collect(),
        TopBy::RowSum => (0..n)
            .map(|i| {
                let mut s = acc.get(i, i);
                for j in (i + 1)..n {
                    s += acc.get(i, j);
                }
                for j in 0..i {
                    s += acc.get(j, i);
                }
                s * inv_w
            })
            .collect(),
    }
}

/// Top-k (index, value) pairs, value-descending with index tiebreak.
/// Uses `total_cmp` (not `partial_cmp` + Equal fallback): snapshots
/// round-trip NaN cells bit-exactly and the library ingest path doesn't
/// forbid them, and a non-total comparator can make `sort_by` panic —
/// which would kill a live serve session mid-query. Under the IEEE total
/// order NaNs land deterministically at the extremes instead.
pub fn top_k_of(values: &[f64], k: usize) -> Vec<(usize, f64)> {
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&a, &b| values[b].total_cmp(&values[a]).then(a.cmp(&b)));
    idx.into_iter()
        .take(k)
        .map(|i| (i, values[i]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shapley::sti_knn::sti_knn;
    use crate::util::rng::Rng;

    fn random_problem(seed: u64, n: usize, d: usize, t: usize) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        (
            (0..n * d).map(|_| rng.normal() as f32).collect(),
            (0..n).map(|_| rng.below(2) as i32).collect(),
            (0..t * d).map(|_| rng.normal() as f32).collect(),
            (0..t).map(|_| rng.below(2) as i32).collect(),
        )
    }

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("stiknn_session_{}_{tag}.snap", std::process::id()))
    }

    #[test]
    fn incremental_ingest_matches_one_shot_bits() {
        let (tx, ty, qx, qy) = random_problem(5, 19, 3, 9);
        let reference = sti_knn(&tx, &ty, 3, &qx, &qy, &StiParams::new(4));
        let mut s = ValuationSession::new(tx, ty, 3, SessionConfig::new(4)).unwrap();
        for (lo, hi) in [(0usize, 2usize), (2, 3), (3, 9)] {
            s.ingest(&qx[lo * 3..hi * 3], &qy[lo..hi]).unwrap();
        }
        assert_eq!(s.tests_seen(), 9);
        assert_eq!(s.ledger().len(), 3);
        let live = s.matrix().unwrap();
        for (a, b) in reference.data().iter().zip(live.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // cell/row agree with the full matrix, including the mirrored side
        assert_eq!(s.cell(7, 2).unwrap().to_bits(), live.get(7, 2).to_bits());
        assert_eq!(s.cell(2, 7), s.cell(7, 2));
        for (j, v) in s.row(5).unwrap().iter().enumerate() {
            assert_eq!(v.to_bits(), live.get(5, j).to_bits());
        }
    }

    #[test]
    fn parallel_ingest_path_is_bit_identical_to_sequential() {
        let (tx, ty, qx, qy) = random_problem(23, 31, 2, 20);
        let mut seq = ValuationSession::new(
            tx.clone(), ty.clone(), 2,
            SessionConfig::new(5).with_parallel_min(1000),
        ).unwrap();
        let mut par = ValuationSession::new(
            tx, ty, 2,
            SessionConfig::new(5).with_parallel_min(1).with_workers(3).with_block_size(4),
        ).unwrap();
        for (lo, hi) in [(0usize, 11usize), (11, 20)] {
            seq.ingest(&qx[lo * 2..hi * 2], &qy[lo..hi]).unwrap();
            par.ingest(&qx[lo * 2..hi * 2], &qy[lo..hi]).unwrap();
        }
        let (a, b) = (seq.matrix().unwrap(), par.matrix().unwrap());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn snapshot_restore_roundtrip_is_bit_identical_and_resumable() {
        let (tx, ty, qx, qy) = random_problem(41, 15, 2, 8);
        let reference = sti_knn(&tx, &ty, 2, &qx, &qy, &StiParams::new(3));

        let mut s = ValuationSession::new(tx.clone(), ty.clone(), 2, SessionConfig::new(3)).unwrap();
        s.ingest(&qx[..5 * 2], &qy[..5]).unwrap();
        let path = temp_path("roundtrip");
        s.save(&path).unwrap();

        let mut restored =
            ValuationSession::restore(&path, tx, ty, 2, SessionConfig::new(3)).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(restored.tests_seen(), 5);
        assert_eq!(restored.ledger(), s.ledger());
        restored.ingest(&qx[5 * 2..], &qy[5..]).unwrap();
        // ledger sequence continues across the restore
        assert_eq!(restored.ledger().last().unwrap().seq, 1);

        let live = restored.matrix().unwrap();
        for (a, b) in reference.data().iter().zip(live.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn restore_rejects_mismatches() {
        let (tx, ty, qx, qy) = random_problem(77, 12, 2, 4);
        let mut s = ValuationSession::new(tx.clone(), ty.clone(), 2, SessionConfig::new(3)).unwrap();
        s.ingest(&qx, &qy).unwrap();
        let path = temp_path("mismatch");
        s.save(&path).unwrap();

        // wrong k
        let err = ValuationSession::restore(&path, tx.clone(), ty.clone(), 2, SessionConfig::new(4))
            .unwrap_err()
            .to_string();
        assert!(err.contains("k="), "{err}");
        // wrong metric
        let err = ValuationSession::restore(
            &path, tx.clone(), ty.clone(), 2,
            SessionConfig::new(3).with_metric(Metric::Manhattan),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("metric"), "{err}");
        // different training data
        let mut tx2 = tx.clone();
        tx2[0] += 1.0;
        let err = ValuationSession::restore(&path, tx2, ty, 2, SessionConfig::new(3))
            .unwrap_err()
            .to_string();
        assert!(err.contains("fingerprint"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_session_queries_are_none_and_stats_zero() {
        let (tx, ty, _, _) = random_problem(9, 10, 2, 1);
        let s = ValuationSession::new(tx, ty, 2, SessionConfig::new(2)).unwrap();
        assert!(s.cell(0, 1).is_none());
        assert!(s.row(0).is_none());
        assert!(s.matrix().is_none());
        assert!(s.top_k(3, TopBy::Main).is_none());
        let st = s.stats();
        assert_eq!(st.tests, 0);
        assert_eq!(st.trace, 0.0);
        assert_eq!(st.mean_offdiag, 0.0);
        // empty ingest is a no-op, not an error
        let mut s = s;
        assert_eq!(s.ingest(&[], &[]).unwrap(), 0);
        assert_eq!(s.ledger().len(), 0);
    }

    #[test]
    fn out_of_range_queries_are_none() {
        let (tx, ty, qx, qy) = random_problem(13, 8, 2, 3);
        let mut s = ValuationSession::new(tx, ty, 2, SessionConfig::new(2)).unwrap();
        s.ingest(&qx, &qy).unwrap();
        assert!(s.cell(0, 8).is_none());
        assert!(s.cell(8, 0).is_none());
        assert!(s.row(8).is_none());
        assert!(s.cell(0, 7).is_some());
    }

    #[test]
    fn topk_and_stats_agree_with_matrix() {
        let (tx, ty, qx, qy) = random_problem(31, 14, 3, 6);
        let mut s = ValuationSession::new(tx, ty, 3, SessionConfig::new(4)).unwrap();
        s.ingest(&qx, &qy).unwrap();
        let m = s.matrix().unwrap();

        let top = s.top_k(14, TopBy::Main).unwrap();
        assert_eq!(top.len(), 14);
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1, "not descending: {top:?}");
        }
        for &(i, v) in &top {
            assert_eq!(v.to_bits(), m.get(i, i).to_bits());
        }

        let rowsum = s.point_values(TopBy::RowSum).unwrap();
        for i in 0..14 {
            let direct: f64 = (0..14).map(|j| m.get(i, j)).sum::<f64>();
            assert!((rowsum[i] - direct).abs() < 1e-12, "row {i}");
        }

        let st = s.stats();
        assert_eq!(st.tests, 6);
        assert_eq!(st.batches, 1);
        assert!((st.trace - m.diagonal().iter().sum::<f64>()).abs() < 1e-12);
        assert!((st.upper_sum - m.upper_triangle_sum()).abs() < 1e-12);
    }

    #[test]
    fn bad_construction_is_rejected() {
        assert!(ValuationSession::new(vec![0.0; 4], vec![0, 1], 2, SessionConfig::new(3)).is_err(),
            "k > n");
        assert!(ValuationSession::new(vec![0.0; 3], vec![0, 1], 2, SessionConfig::new(1)).is_err(),
            "shape mismatch");
        assert!(ValuationSession::new(vec![0.0; 2], vec![0], 2, SessionConfig::new(1)).is_err(),
            "n < 2");
        let mut s =
            ValuationSession::new(vec![0.0, 0.1, 1.0, 1.1], vec![0, 1], 2, SessionConfig::new(1))
                .unwrap();
        assert!(s.ingest(&[0.5], &[0]).is_err(), "batch shape mismatch");
    }

    #[test]
    fn ledger_compaction_bounds_state_and_preserves_invariants() {
        let (tx, ty, qx, qy) = random_problem(61, 6, 1, 1);
        let reference_batches = (LEDGER_COMPACT_AT as u64) + 50;
        let mut s = ValuationSession::new(tx, ty, 1, SessionConfig::new(2)).unwrap();
        for _ in 0..reference_batches {
            s.ingest(&qx, &qy).unwrap();
        }
        // compaction kept the ledger bounded...
        assert!(s.ledger().len() < LEDGER_COMPACT_AT, "{}", s.ledger().len());
        // ...while the batch count and the Σ len == tests invariant hold
        assert_eq!(s.batches_ingested(), reference_batches);
        assert_eq!(s.stats().batches, reference_batches);
        assert_eq!(s.tests_seen(), reference_batches);
        let total: u64 = s.ledger().iter().map(|b| b.len).sum();
        assert_eq!(total, s.tests_seen());
        // a snapshot of the compacted ledger round-trips (decode re-checks
        // the sum invariant) and the restored session keeps counting
        let path = temp_path("compaction");
        s.save(&path).unwrap();
        let (tx, ty, qx, qy) = random_problem(61, 6, 1, 1);
        let mut restored = ValuationSession::restore(&path, tx, ty, 1, SessionConfig::new(2))
            .unwrap();
        let _ = std::fs::remove_file(&path);
        restored.ingest(&qx, &qy).unwrap();
        assert_eq!(restored.batches_ingested(), reference_batches + 1);
    }

    #[test]
    fn top_k_of_truncates_and_tiebreaks_by_index() {
        let top = top_k_of(&[1.0, 3.0, 3.0, -1.0], 3);
        assert_eq!(top, vec![(1, 3.0), (2, 3.0), (0, 1.0)]);
        assert_eq!(top_k_of(&[1.0], 5), vec![(0, 1.0)]);
    }
}
