//! Versioned binary snapshot store for [`ValuationSession`]s
//! (DESIGN.md §9).
//!
//! A snapshot captures everything a session needs to resume exactly where
//! it left off: the RAW (unnormalized) accumulator, the test count, and
//! the per-batch weight ledger, guarded by enough metadata to refuse a
//! mismatched resume (k, metric, train-set fingerprint). Restore is
//! **bit-identical**: f64 cells round-trip through `to_le_bytes`/
//! `from_le_bytes`, which preserve every bit pattern including ±0 and
//! NaN payloads, so a snapshot/restore cycle mid-stream cannot perturb
//! the final matrix (asserted by `tests/session_equivalence.rs`).
//!
//! ## Format (version 1, all integers and floats little-endian)
//!
//! ```text
//! offset  size        field
//! 0       8           magic  b"STIKNNSS"
//! 8       4           format version (u32) = 1
//! 12      4           k (u32)
//! 16      1           metric tag (u8): 0 = sqeuclidean, 1 = manhattan, 2 = cosine
//! 17      8           n, train-set size (u64)
//! 25      8           d, feature dimension (u64)
//! 33      8           train-set fingerprint (u64, FNV-1a over d, n, features, labels)
//! 41      8           total test points ingested (u64)
//! 49      8           ledger length L (u64)
//! 57      16·L        ledger entries: (seq u64, len u64) per ingested batch
//! 57+16L  8·n²        raw accumulator, row-major f64 (upper triangle + diagonal)
//! end−8   8           FNV-1a checksum over every preceding byte (u64)
//! ```

use super::BatchRecord;
use crate::knn::distance::Metric;
use crate::util::matrix::Matrix;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

/// First 8 bytes of every snapshot file.
pub const MAGIC: [u8; 8] = *b"STIKNNSS";

/// Current snapshot format version.
pub const VERSION: u32 = 1;

/// Decoded snapshot metadata (everything but the ledger and the matrix).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SnapshotHeader {
    pub version: u32,
    pub k: u32,
    pub metric: Metric,
    pub n: u64,
    pub d: u64,
    pub fingerprint: u64,
    pub tests: u64,
    /// Ledger ENTRY count — after compaction one entry may cover many
    /// ingests; the lifetime batch count is `last ledger seq + 1`.
    pub batches: u64,
}

/// A fully decoded (and checksum-verified) snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub header: SnapshotHeader,
    pub ledger: Vec<BatchRecord>,
    /// RAW accumulator as stored: unnormalized, upper triangle + diagonal
    /// populated, strict lower triangle all zeros.
    pub raw: Matrix,
}

impl Snapshot {
    /// The averaged interaction matrix this snapshot represents (mirror +
    /// scale by 1/tests, exactly like the live session / one-shot
    /// `sti_knn`). `None` before any test points were ingested.
    pub fn averaged_matrix(&self) -> Option<Matrix> {
        if self.header.tests == 0 {
            return None;
        }
        let mut m = self.raw.clone();
        m.mirror_upper_to_lower();
        m.scale(1.0 / self.header.tests as f64);
        Some(m)
    }

    /// Top-k point values straight from the snapshot (no training data
    /// needed). `None` before any test points were ingested.
    pub fn top_k(&self, k: usize, by: super::TopBy) -> Option<Vec<(usize, f64)>> {
        if self.header.tests == 0 {
            return None;
        }
        let values = super::point_values_raw(&self.raw, 1.0 / self.header.tests as f64, by);
        Some(super::top_k_of(&values, k))
    }
}

/// Stable wire tag for a metric (part of the snapshot format — never
/// renumber existing variants).
pub fn metric_tag(metric: Metric) -> u8 {
    match metric {
        Metric::SqEuclidean => 0,
        Metric::Manhattan => 1,
        Metric::Cosine => 2,
    }
}

/// Inverse of [`metric_tag`].
pub fn metric_from_tag(tag: u8) -> Option<Metric> {
    match tag {
        0 => Some(Metric::SqEuclidean),
        1 => Some(Metric::Manhattan),
        2 => Some(Metric::Cosine),
        _ => None,
    }
}

/// Incremental FNV-1a (64-bit) — the snapshot checksum and the train-set
/// fingerprint hash. Not cryptographic; detects corruption and honest
/// mismatches, which is the contract here.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// Identity of a training set for snapshot-compatibility checks: FNV-1a
/// over (d, n, feature bits, labels). Two train sets fingerprint equal
/// iff they are bitwise the same data in the same order — exactly the
/// condition under which a resumed session keeps producing bit-identical
/// results.
pub fn dataset_fingerprint(train_x: &[f32], train_y: &[i32], d: usize) -> u64 {
    let mut h = Fnv::new();
    h.write(&(d as u64).to_le_bytes());
    h.write(&(train_y.len() as u64).to_le_bytes());
    for v in train_x {
        h.write(&v.to_le_bytes());
    }
    for v in train_y {
        h.write(&v.to_le_bytes());
    }
    h.finish()
}

/// Serialize one snapshot to its byte representation.
#[allow(clippy::too_many_arguments)]
pub fn encode(
    k: u32,
    metric: Metric,
    n: u64,
    d: u64,
    fingerprint: u64,
    tests: u64,
    ledger: &[BatchRecord],
    raw: &[f64],
) -> Vec<u8> {
    assert_eq!(raw.len() as u64, n * n, "raw accumulator shape mismatch");
    let mut out = Vec::with_capacity(57 + 16 * ledger.len() + 8 * raw.len() + 8);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&k.to_le_bytes());
    out.push(metric_tag(metric));
    out.extend_from_slice(&n.to_le_bytes());
    out.extend_from_slice(&d.to_le_bytes());
    out.extend_from_slice(&fingerprint.to_le_bytes());
    out.extend_from_slice(&tests.to_le_bytes());
    out.extend_from_slice(&(ledger.len() as u64).to_le_bytes());
    for rec in ledger {
        out.extend_from_slice(&rec.seq.to_le_bytes());
        out.extend_from_slice(&rec.len.to_le_bytes());
    }
    for v in raw {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let mut h = Fnv::new();
    h.write(&out);
    let checksum = h.finish();
    out.extend_from_slice(&checksum.to_le_bytes());
    out
}

/// Byte-stream cursor for decoding.
struct Rd<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, len: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + len <= self.bytes.len(),
            "snapshot truncated at byte {} (wanted {} more)",
            self.pos,
            len
        );
        let s = &self.bytes[self.pos..self.pos + len];
        self.pos += len;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Decode and fully validate a snapshot byte stream (magic, version,
/// checksum, internal consistency).
pub fn decode(bytes: &[u8]) -> Result<Snapshot> {
    ensure!(bytes.len() >= 57 + 8, "snapshot too short ({} bytes)", bytes.len());
    // Checksum first: everything else assumes intact bytes.
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(tail.try_into().unwrap());
    let mut h = Fnv::new();
    h.write(body);
    ensure!(
        h.finish() == stored,
        "snapshot checksum mismatch (file corrupt or not a snapshot)"
    );

    let mut rd = Rd { bytes: body, pos: 0 };
    let magic = rd.take(8)?;
    ensure!(magic == &MAGIC[..], "bad snapshot magic {:02x?}", magic);
    let version = rd.u32()?;
    if version != VERSION {
        bail!("unsupported snapshot version {version} (this build reads version {VERSION})");
    }
    let k = rd.u32()?;
    let metric_tag = rd.u8()?;
    let Some(metric) = metric_from_tag(metric_tag) else {
        bail!("unknown metric tag {metric_tag} in snapshot");
    };
    let n = rd.u64()?;
    let d = rd.u64()?;
    let fingerprint = rd.u64()?;
    let tests = rd.u64()?;
    let ledger_len = rd.u64()?;

    // Shape sanity BEFORE allocating anything sized by file contents: the
    // remaining body must be exactly ledger + matrix. Every multiplication
    // is checked — a crafted header must produce a clean error, not a
    // wrap-around that defeats this guard (the checksum is FNV, not a MAC,
    // so headers are attacker-controllable).
    let expected = (ledger_len as usize).checked_mul(16).and_then(|l| {
        (n as usize)
            .checked_mul(n as usize)
            .and_then(|m| m.checked_mul(8))
            .map(|mb| (l, mb))
    });
    let Some(expected_bytes) = expected
        .and_then(|(ledger_bytes, matrix_bytes)| ledger_bytes.checked_add(matrix_bytes))
    else {
        bail!("snapshot header sizes overflow (n={n}, ledger={ledger_len})");
    };
    ensure!(
        body.len() - rd.pos == expected_bytes,
        "snapshot body is {} bytes but header implies {} (n={n}, ledger={ledger_len})",
        body.len() - rd.pos,
        expected_bytes
    );

    let mut ledger = Vec::with_capacity(ledger_len as usize);
    let mut ledger_total = 0u64;
    for _ in 0..ledger_len {
        let seq = rd.u64()?;
        let len = rd.u64()?;
        ledger_total = ledger_total
            .checked_add(len)
            .ok_or_else(|| anyhow::anyhow!("weight ledger sum overflows u64"))?;
        ledger.push(BatchRecord { seq, len });
    }
    ensure!(
        ledger_total == tests,
        "weight ledger sums to {ledger_total} but snapshot records {tests} tests"
    );

    let cells = (n * n) as usize;
    let mut raw = Vec::with_capacity(cells);
    for _ in 0..cells {
        raw.push(rd.f64()?);
    }

    Ok(Snapshot {
        header: SnapshotHeader {
            version,
            k,
            metric,
            n,
            d,
            fingerprint,
            tests,
            batches: ledger_len,
        },
        ledger,
        raw: Matrix::from_vec(n as usize, n as usize, raw),
    })
}

/// Read + decode a snapshot file.
pub fn read_snapshot(path: &Path) -> Result<Snapshot> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading snapshot {}", path.display()))?;
    decode(&bytes).with_context(|| format!("decoding snapshot {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        let raw: Vec<f64> = (0..9).map(|i| i as f64 * 0.25 - 1.0).collect();
        encode(
            3,
            Metric::SqEuclidean,
            3,
            2,
            0xDEAD_BEEF,
            5,
            &[BatchRecord { seq: 0, len: 2 }, BatchRecord { seq: 1, len: 3 }],
            &raw,
        )
    }

    #[test]
    fn roundtrip_preserves_everything_bitwise() {
        let bytes = sample();
        let snap = decode(&bytes).unwrap();
        assert_eq!(snap.header.version, VERSION);
        assert_eq!(snap.header.k, 3);
        assert_eq!(snap.header.metric, Metric::SqEuclidean);
        assert_eq!(snap.header.n, 3);
        assert_eq!(snap.header.d, 2);
        assert_eq!(snap.header.fingerprint, 0xDEAD_BEEF);
        assert_eq!(snap.header.tests, 5);
        assert_eq!(snap.header.batches, 2);
        assert_eq!(snap.ledger, vec![
            BatchRecord { seq: 0, len: 2 },
            BatchRecord { seq: 1, len: 3 },
        ]);
        for (i, v) in snap.raw.data().iter().enumerate() {
            assert_eq!(v.to_bits(), (i as f64 * 0.25 - 1.0).to_bits());
        }
        // re-encoding the decoded snapshot reproduces the bytes exactly
        let again = encode(3, Metric::SqEuclidean, 3, 2, 0xDEAD_BEEF, 5, &snap.ledger,
            snap.raw.data());
        assert_eq!(bytes, again);
    }

    #[test]
    fn nan_and_negative_zero_cells_survive() {
        let raw = vec![f64::NAN, -0.0, f64::INFINITY, 1.5];
        let bytes = encode(1, Metric::Cosine, 2, 1, 7, 1,
            &[BatchRecord { seq: 0, len: 1 }], &raw);
        let snap = decode(&bytes).unwrap();
        for (a, b) in raw.iter().zip(snap.raw.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn corruption_is_detected() {
        let mut bytes = sample();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn truncation_is_detected() {
        let bytes = sample();
        assert!(decode(&bytes[..bytes.len() - 9]).is_err());
        assert!(decode(&bytes[..20]).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample();
        bytes[0] = b'X';
        // checksum fails first (it covers the magic); flipping magic AND
        // refreshing the checksum must then hit the magic check itself
        let body_len = bytes.len() - 8;
        let mut h = Fnv::new();
        h.write(&bytes[..body_len]);
        let sum = h.finish().to_le_bytes();
        bytes[body_len..].copy_from_slice(&sum);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn future_version_rejected_with_clear_error() {
        let mut bytes = sample();
        bytes[8..12].copy_from_slice(&9u32.to_le_bytes());
        let body_len = bytes.len() - 8;
        let mut h = Fnv::new();
        h.write(&bytes[..body_len]);
        let sum = h.finish().to_le_bytes();
        bytes[body_len..].copy_from_slice(&sum);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("version 9"), "{err}");
    }

    #[test]
    fn ledger_total_must_match_tests() {
        let raw = vec![0.0; 4];
        let bytes = encode(1, Metric::SqEuclidean, 2, 1, 0, 99,
            &[BatchRecord { seq: 0, len: 1 }], &raw);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("ledger"), "{err}");
    }

    #[test]
    fn metric_tags_are_stable_and_invertible() {
        for m in [Metric::SqEuclidean, Metric::Manhattan, Metric::Cosine] {
            assert_eq!(metric_from_tag(metric_tag(m)), Some(m));
        }
        assert_eq!(metric_from_tag(3), None);
    }

    #[test]
    fn fingerprint_sensitive_to_data_and_layout() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let y = vec![0i32, 1];
        let base = dataset_fingerprint(&x, &y, 2);
        assert_eq!(base, dataset_fingerprint(&x, &y, 2), "deterministic");
        let mut x2 = x.clone();
        x2[3] = 4.0000005;
        assert_ne!(base, dataset_fingerprint(&x2, &y, 2), "feature change");
        assert_ne!(base, dataset_fingerprint(&x, &[0, 0], 2), "label change");
        assert_ne!(
            base,
            dataset_fingerprint(&x, &[0, 1, 0, 1], 1),
            "same bytes, different shape"
        );
    }
}
