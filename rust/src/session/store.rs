//! Versioned binary snapshot store for [`ValuationSession`]s
//! (DESIGN.md §9/§10).
//!
//! A snapshot captures everything a session needs to resume exactly where
//! it left off: the engine payload (RAW unnormalized accumulator for
//! dense sessions, RAW value vector for implicit ones), the test count,
//! and the per-batch weight ledger, guarded by enough metadata to refuse
//! a mismatched resume (k, metric, train-set fingerprint). Restore is
//! **bit-identical**: f64 cells round-trip through `to_le_bytes`/
//! `from_le_bytes`, which preserve every bit pattern including ±0 and
//! NaN payloads, so a snapshot/restore cycle mid-stream cannot perturb
//! the final state (asserted by `tests/session_equivalence.rs` and
//! `tests/values_equivalence.rs`).
//!
//! ## Format (version 2, all integers and floats little-endian)
//!
//! ```text
//! offset  size        field
//! 0       8           magic  b"STIKNNSS"
//! 8       4           format version (u32) = 2
//! 12      4           k (u32)
//! 16      1           metric tag (u8): 0 = sqeuclidean, 1 = manhattan, 2 = cosine
//! 17      1           payload kind (u8): 0 = dense matrix, 1 = implicit value vector
//! 18      8           n, train-set size (u64)
//! 26      8           d, feature dimension (u64)
//! 34      8           train-set fingerprint (u64, FNV-1a over d, n, features, labels)
//! 42      8           total test points ingested (u64)
//! 50      8           ledger length L (u64)
//! 58      16·L        ledger entries: (seq u64, len u64) per ingested batch
//! 58+16L  payload     kind 0: 8·n² raw accumulator, row-major f64
//!                             (upper triangle + diagonal)
//!                     kind 1: 8·n raw main sums, then 8·n raw
//!                             interaction-rowsum sums (f64 each)
//! end−8   8           FNV-1a checksum over every preceding byte (u64)
//! ```
//!
//! Version 1 files (written before the implicit engine existed) are the
//! same layout WITHOUT the payload-kind byte and always carry a dense
//! matrix payload; [`decode`] still reads them, so old snapshots restore
//! into current builds.

use super::BatchRecord;
use crate::knn::distance::Metric;
use crate::shapley::values::Engine;
use crate::util::matrix::Matrix;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

/// First 8 bytes of every snapshot file.
pub const MAGIC: [u8; 8] = *b"STIKNNSS";

/// Current snapshot format version.
pub const VERSION: u32 = 2;

/// Oldest version [`decode`] still reads.
pub const MIN_VERSION: u32 = 1;

/// Decoded snapshot metadata (everything but the ledger and the payload).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SnapshotHeader {
    pub version: u32,
    pub k: u32,
    pub metric: Metric,
    /// Which engine wrote the payload (v1 files are always `Dense`).
    pub engine: Engine,
    pub n: u64,
    pub d: u64,
    pub fingerprint: u64,
    pub tests: u64,
    /// Ledger ENTRY count — after compaction one entry may cover many
    /// ingests; the lifetime batch count is `last ledger seq + 1`.
    pub batches: u64,
}

/// The engine-specific state a snapshot carries (both raw/unnormalized).
#[derive(Clone, Debug)]
pub enum SnapshotPayload {
    /// Accumulator as stored: upper triangle + diagonal populated,
    /// strict lower triangle all zeros.
    Dense(Matrix),
    /// Value vector sums: `main[i]` = Σ_p u_p(i), `inter[i]` =
    /// Σ_p Σ_{j≠i} φ_p[i,j].
    Implicit { main: Vec<f64>, inter: Vec<f64> },
}

/// A fully decoded (and checksum-verified) snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub header: SnapshotHeader,
    pub ledger: Vec<BatchRecord>,
    pub payload: SnapshotPayload,
}

impl Snapshot {
    /// The averaged interaction matrix this snapshot represents (mirror +
    /// scale by 1/tests, exactly like the live session / one-shot
    /// `sti_knn`). `None` before any test points were ingested or when
    /// the payload is a value vector (implicit sessions never had one).
    pub fn averaged_matrix(&self) -> Option<Matrix> {
        if self.header.tests == 0 {
            return None;
        }
        match &self.payload {
            SnapshotPayload::Dense(raw) => {
                let mut m = raw.clone();
                m.mirror_upper_to_lower();
                m.scale(1.0 / self.header.tests as f64);
                Some(m)
            }
            SnapshotPayload::Implicit { .. } => None,
        }
    }

    /// Averaged per-point values straight from the snapshot (no training
    /// data needed) — works for BOTH payload kinds. `None` before any
    /// test points were ingested.
    pub fn point_values(&self, by: super::TopBy) -> Option<Vec<f64>> {
        if self.header.tests == 0 {
            return None;
        }
        let inv_w = 1.0 / self.header.tests as f64;
        Some(match &self.payload {
            SnapshotPayload::Dense(raw) => super::point_values_raw(raw, inv_w, by),
            SnapshotPayload::Implicit { main, inter } => match by {
                super::TopBy::Main => main.iter().map(|&m| m * inv_w).collect(),
                super::TopBy::RowSum => main
                    .iter()
                    .zip(inter)
                    .map(|(&m, &s)| (m + s) * inv_w)
                    .collect(),
            },
        })
    }

    /// Top-k point values straight from the snapshot. `None` before any
    /// test points were ingested.
    pub fn top_k(&self, k: usize, by: super::TopBy) -> Option<Vec<(usize, f64)>> {
        Some(super::top_k_of(&self.point_values(by)?, k))
    }
}

/// Stable wire tag for a metric (part of the snapshot format — never
/// renumber existing variants).
pub fn metric_tag(metric: Metric) -> u8 {
    match metric {
        Metric::SqEuclidean => 0,
        Metric::Manhattan => 1,
        Metric::Cosine => 2,
    }
}

/// Inverse of [`metric_tag`].
pub fn metric_from_tag(tag: u8) -> Option<Metric> {
    match tag {
        0 => Some(Metric::SqEuclidean),
        1 => Some(Metric::Manhattan),
        2 => Some(Metric::Cosine),
        _ => None,
    }
}

/// Stable wire tag for a payload kind (never renumber).
pub fn payload_tag(engine: Engine) -> u8 {
    match engine {
        Engine::Dense => 0,
        Engine::Implicit => 1,
    }
}

/// Inverse of [`payload_tag`].
pub fn engine_from_tag(tag: u8) -> Option<Engine> {
    match tag {
        0 => Some(Engine::Dense),
        1 => Some(Engine::Implicit),
        _ => None,
    }
}

/// Incremental FNV-1a (64-bit) — the snapshot checksum and the train-set
/// fingerprint hash. Not cryptographic; detects corruption and honest
/// mismatches, which is the contract here.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// Identity of a training set for snapshot-compatibility checks: FNV-1a
/// over (d, n, feature bits, labels). Two train sets fingerprint equal
/// iff they are bitwise the same data in the same order — exactly the
/// condition under which a resumed session keeps producing bit-identical
/// results.
pub fn dataset_fingerprint(train_x: &[f32], train_y: &[i32], d: usize) -> u64 {
    let mut h = Fnv::new();
    h.write(&(d as u64).to_le_bytes());
    h.write(&(train_y.len() as u64).to_le_bytes());
    for v in train_x {
        h.write(&v.to_le_bytes());
    }
    for v in train_y {
        h.write(&v.to_le_bytes());
    }
    h.finish()
}

/// Borrowed payload for [`encode`].
#[derive(Clone, Copy, Debug)]
pub enum EncodePayload<'a> {
    /// Raw n×n accumulator, row-major.
    Dense(&'a [f64]),
    /// Raw value-vector sums, n each.
    Implicit { main: &'a [f64], inter: &'a [f64] },
}

/// Serialize one snapshot to its byte representation (always the current
/// format version).
#[allow(clippy::too_many_arguments)]
pub fn encode(
    k: u32,
    metric: Metric,
    n: u64,
    d: u64,
    fingerprint: u64,
    tests: u64,
    ledger: &[BatchRecord],
    payload: EncodePayload<'_>,
) -> Vec<u8> {
    let (kind, payload_len) = match payload {
        EncodePayload::Dense(raw) => {
            assert_eq!(raw.len() as u64, n * n, "raw accumulator shape mismatch");
            (Engine::Dense, raw.len())
        }
        EncodePayload::Implicit { main, inter } => {
            assert_eq!(main.len() as u64, n, "main vector shape mismatch");
            assert_eq!(inter.len() as u64, n, "inter vector shape mismatch");
            (Engine::Implicit, main.len() + inter.len())
        }
    };
    let mut out = Vec::with_capacity(58 + 16 * ledger.len() + 8 * payload_len + 8);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&k.to_le_bytes());
    out.push(metric_tag(metric));
    out.push(payload_tag(kind));
    out.extend_from_slice(&n.to_le_bytes());
    out.extend_from_slice(&d.to_le_bytes());
    out.extend_from_slice(&fingerprint.to_le_bytes());
    out.extend_from_slice(&tests.to_le_bytes());
    out.extend_from_slice(&(ledger.len() as u64).to_le_bytes());
    for rec in ledger {
        out.extend_from_slice(&rec.seq.to_le_bytes());
        out.extend_from_slice(&rec.len.to_le_bytes());
    }
    match payload {
        EncodePayload::Dense(raw) => {
            for v in raw {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        EncodePayload::Implicit { main, inter } => {
            for v in main {
                out.extend_from_slice(&v.to_le_bytes());
            }
            for v in inter {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    let mut h = Fnv::new();
    h.write(&out);
    let checksum = h.finish();
    out.extend_from_slice(&checksum.to_le_bytes());
    out
}

/// Byte-stream cursor for decoding.
struct Rd<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, len: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + len <= self.bytes.len(),
            "snapshot truncated at byte {} (wanted {} more)",
            self.pos,
            len
        );
        let s = &self.bytes[self.pos..self.pos + len];
        self.pos += len;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64_vec(&mut self, len: usize) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.f64()?);
        }
        Ok(out)
    }
}

/// Decode and fully validate a snapshot byte stream (magic, version,
/// checksum, internal consistency). Reads versions [`MIN_VERSION`]
/// through [`VERSION`].
pub fn decode(bytes: &[u8]) -> Result<Snapshot> {
    ensure!(bytes.len() >= 57 + 8, "snapshot too short ({} bytes)", bytes.len());
    // Checksum first: everything else assumes intact bytes.
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(tail.try_into().unwrap());
    let mut h = Fnv::new();
    h.write(body);
    ensure!(
        h.finish() == stored,
        "snapshot checksum mismatch (file corrupt or not a snapshot)"
    );

    let mut rd = Rd { bytes: body, pos: 0 };
    let magic = rd.take(8)?;
    ensure!(magic == &MAGIC[..], "bad snapshot magic {:02x?}", magic);
    let version = rd.u32()?;
    if !(MIN_VERSION..=VERSION).contains(&version) {
        bail!(
            "unsupported snapshot version {version} (this build reads versions \
             {MIN_VERSION}..={VERSION})"
        );
    }
    let k = rd.u32()?;
    let metric_tag = rd.u8()?;
    let Some(metric) = metric_from_tag(metric_tag) else {
        bail!("unknown metric tag {metric_tag} in snapshot");
    };
    // v1 predates the payload-kind byte: those files are always dense.
    let engine = if version >= 2 {
        let tag = rd.u8()?;
        let Some(engine) = engine_from_tag(tag) else {
            bail!("unknown payload kind {tag} in snapshot");
        };
        engine
    } else {
        Engine::Dense
    };
    let n = rd.u64()?;
    let d = rd.u64()?;
    let fingerprint = rd.u64()?;
    let tests = rd.u64()?;
    let ledger_len = rd.u64()?;

    // Shape sanity BEFORE allocating anything sized by file contents: the
    // remaining body must be exactly ledger + payload. Every multiplication
    // is checked — a crafted header must produce a clean error, not a
    // wrap-around that defeats this guard (the checksum is FNV, not a MAC,
    // so headers are attacker-controllable).
    let payload_cells = match engine {
        Engine::Dense => (n as usize).checked_mul(n as usize),
        Engine::Implicit => (n as usize).checked_mul(2),
    };
    let expected = (ledger_len as usize).checked_mul(16).and_then(|l| {
        payload_cells
            .and_then(|m| m.checked_mul(8))
            .map(|mb| (l, mb))
    });
    let Some(expected_bytes) = expected
        .and_then(|(ledger_bytes, payload_bytes)| ledger_bytes.checked_add(payload_bytes))
    else {
        bail!("snapshot header sizes overflow (n={n}, ledger={ledger_len})");
    };
    ensure!(
        body.len() - rd.pos == expected_bytes,
        "snapshot body is {} bytes but header implies {} (n={n}, ledger={ledger_len})",
        body.len() - rd.pos,
        expected_bytes
    );

    let mut ledger = Vec::with_capacity(ledger_len as usize);
    let mut ledger_total = 0u64;
    for _ in 0..ledger_len {
        let seq = rd.u64()?;
        let len = rd.u64()?;
        ledger_total = ledger_total
            .checked_add(len)
            .ok_or_else(|| anyhow::anyhow!("weight ledger sum overflows u64"))?;
        ledger.push(BatchRecord { seq, len });
    }
    ensure!(
        ledger_total == tests,
        "weight ledger sums to {ledger_total} but snapshot records {tests} tests"
    );

    let payload = match engine {
        Engine::Dense => {
            let raw = rd.f64_vec((n * n) as usize)?;
            SnapshotPayload::Dense(Matrix::from_vec(n as usize, n as usize, raw))
        }
        Engine::Implicit => {
            let main = rd.f64_vec(n as usize)?;
            let inter = rd.f64_vec(n as usize)?;
            SnapshotPayload::Implicit { main, inter }
        }
    };

    Ok(Snapshot {
        header: SnapshotHeader {
            version,
            k,
            metric,
            engine,
            n,
            d,
            fingerprint,
            tests,
            batches: ledger_len,
        },
        ledger,
        payload,
    })
}

/// Read + decode a snapshot file.
pub fn read_snapshot(path: &Path) -> Result<Snapshot> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading snapshot {}", path.display()))?;
    decode(&bytes).with_context(|| format!("decoding snapshot {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        let raw: Vec<f64> = (0..9).map(|i| i as f64 * 0.25 - 1.0).collect();
        encode(
            3,
            Metric::SqEuclidean,
            3,
            2,
            0xDEAD_BEEF,
            5,
            &[BatchRecord { seq: 0, len: 2 }, BatchRecord { seq: 1, len: 3 }],
            EncodePayload::Dense(&raw),
        )
    }

    fn sample_implicit() -> Vec<u8> {
        encode(
            2,
            Metric::Manhattan,
            3,
            4,
            0xFEED_F00D,
            7,
            &[BatchRecord { seq: 0, len: 7 }],
            EncodePayload::Implicit {
                main: &[0.5, 0.0, 1.5],
                inter: &[-0.25, 0.75, -1.0],
            },
        )
    }

    /// Hand-build a VERSION-1 byte stream (pre-implicit layout: no
    /// payload-kind byte, dense matrix payload) — the read-compat fixture.
    fn sample_v1() -> Vec<u8> {
        let raw: Vec<f64> = (0..4).map(|i| i as f64).collect();
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&2u32.to_le_bytes()); // k
        out.push(metric_tag(Metric::SqEuclidean));
        out.extend_from_slice(&2u64.to_le_bytes()); // n
        out.extend_from_slice(&1u64.to_le_bytes()); // d
        out.extend_from_slice(&0x1234u64.to_le_bytes()); // fingerprint
        out.extend_from_slice(&3u64.to_le_bytes()); // tests
        out.extend_from_slice(&1u64.to_le_bytes()); // ledger len
        out.extend_from_slice(&0u64.to_le_bytes()); // seq
        out.extend_from_slice(&3u64.to_le_bytes()); // len
        for v in &raw {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let mut h = Fnv::new();
        h.write(&out);
        let sum = h.finish();
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    #[test]
    fn roundtrip_preserves_everything_bitwise() {
        let bytes = sample();
        let snap = decode(&bytes).unwrap();
        assert_eq!(snap.header.version, VERSION);
        assert_eq!(snap.header.k, 3);
        assert_eq!(snap.header.metric, Metric::SqEuclidean);
        assert_eq!(snap.header.engine, Engine::Dense);
        assert_eq!(snap.header.n, 3);
        assert_eq!(snap.header.d, 2);
        assert_eq!(snap.header.fingerprint, 0xDEAD_BEEF);
        assert_eq!(snap.header.tests, 5);
        assert_eq!(snap.header.batches, 2);
        assert_eq!(snap.ledger, vec![
            BatchRecord { seq: 0, len: 2 },
            BatchRecord { seq: 1, len: 3 },
        ]);
        let SnapshotPayload::Dense(raw) = &snap.payload else {
            panic!("dense payload expected");
        };
        for (i, v) in raw.data().iter().enumerate() {
            assert_eq!(v.to_bits(), (i as f64 * 0.25 - 1.0).to_bits());
        }
        // re-encoding the decoded snapshot reproduces the bytes exactly
        let again = encode(3, Metric::SqEuclidean, 3, 2, 0xDEAD_BEEF, 5, &snap.ledger,
            EncodePayload::Dense(raw.data()));
        assert_eq!(bytes, again);
    }

    #[test]
    fn implicit_payload_roundtrips_bitwise() {
        let bytes = sample_implicit();
        let snap = decode(&bytes).unwrap();
        assert_eq!(snap.header.engine, Engine::Implicit);
        assert_eq!(snap.header.tests, 7);
        let SnapshotPayload::Implicit { main, inter } = &snap.payload else {
            panic!("implicit payload expected");
        };
        assert_eq!(main.as_slice(), &[0.5, 0.0, 1.5]);
        assert_eq!(inter.as_slice(), &[-0.25, 0.75, -1.0]);
        // no matrix ever existed → averaged_matrix is None, values work
        assert!(snap.averaged_matrix().is_none());
        let top = snap.top_k(3, crate::session::TopBy::RowSum).unwrap();
        // rowsum/7: [0.25/7, 0.75/7, 0.5/7] → index order 1, 2, 0
        assert_eq!(top[0].0, 1);
        assert_eq!(top[1].0, 2);
        assert_eq!(top[2].0, 0);
        let again = encode(2, Metric::Manhattan, 3, 4, 0xFEED_F00D, 7, &snap.ledger,
            EncodePayload::Implicit { main: main.as_slice(), inter: inter.as_slice() });
        assert_eq!(bytes, again);
    }

    #[test]
    fn version_1_files_still_decode() {
        let snap = decode(&sample_v1()).unwrap();
        assert_eq!(snap.header.version, 1);
        assert_eq!(snap.header.engine, Engine::Dense, "v1 is always dense");
        assert_eq!(snap.header.n, 2);
        assert_eq!(snap.header.tests, 3);
        let SnapshotPayload::Dense(raw) = &snap.payload else {
            panic!("dense payload expected");
        };
        assert_eq!(raw.data(), &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn nan_and_negative_zero_cells_survive() {
        let raw = vec![f64::NAN, -0.0, f64::INFINITY, 1.5];
        let bytes = encode(1, Metric::Cosine, 2, 1, 7, 1,
            &[BatchRecord { seq: 0, len: 1 }], EncodePayload::Dense(&raw));
        let snap = decode(&bytes).unwrap();
        let SnapshotPayload::Dense(m) = &snap.payload else {
            panic!("dense payload expected");
        };
        for (a, b) in raw.iter().zip(m.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn corruption_is_detected() {
        let mut bytes = sample();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn truncation_is_detected() {
        let bytes = sample();
        assert!(decode(&bytes[..bytes.len() - 9]).is_err());
        assert!(decode(&bytes[..20]).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample();
        bytes[0] = b'X';
        // checksum fails first (it covers the magic); flipping magic AND
        // refreshing the checksum must then hit the magic check itself
        let body_len = bytes.len() - 8;
        let mut h = Fnv::new();
        h.write(&bytes[..body_len]);
        let sum = h.finish().to_le_bytes();
        bytes[body_len..].copy_from_slice(&sum);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn future_version_rejected_with_clear_error() {
        let mut bytes = sample();
        bytes[8..12].copy_from_slice(&9u32.to_le_bytes());
        let body_len = bytes.len() - 8;
        let mut h = Fnv::new();
        h.write(&bytes[..body_len]);
        let sum = h.finish().to_le_bytes();
        bytes[body_len..].copy_from_slice(&sum);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("version 9"), "{err}");
    }

    #[test]
    fn unknown_payload_kind_rejected() {
        let mut bytes = sample();
        bytes[17] = 9; // payload-kind byte
        let body_len = bytes.len() - 8;
        let mut h = Fnv::new();
        h.write(&bytes[..body_len]);
        let sum = h.finish().to_le_bytes();
        bytes[body_len..].copy_from_slice(&sum);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("payload kind"), "{err}");
    }

    #[test]
    fn ledger_total_must_match_tests() {
        let raw = vec![0.0; 4];
        let bytes = encode(1, Metric::SqEuclidean, 2, 1, 0, 99,
            &[BatchRecord { seq: 0, len: 1 }], EncodePayload::Dense(&raw));
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("ledger"), "{err}");
    }

    #[test]
    fn metric_tags_are_stable_and_invertible() {
        for m in [Metric::SqEuclidean, Metric::Manhattan, Metric::Cosine] {
            assert_eq!(metric_from_tag(metric_tag(m)), Some(m));
        }
        assert_eq!(metric_from_tag(3), None);
    }

    #[test]
    fn payload_tags_are_stable_and_invertible() {
        assert_eq!(payload_tag(Engine::Dense), 0);
        assert_eq!(payload_tag(Engine::Implicit), 1);
        for e in [Engine::Dense, Engine::Implicit] {
            assert_eq!(engine_from_tag(payload_tag(e)), Some(e));
        }
        assert_eq!(engine_from_tag(2), None);
    }

    #[test]
    fn fingerprint_sensitive_to_data_and_layout() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let y = vec![0i32, 1];
        let base = dataset_fingerprint(&x, &y, 2);
        assert_eq!(base, dataset_fingerprint(&x, &y, 2), "deterministic");
        let mut x2 = x.clone();
        x2[3] = 4.0000005;
        assert_ne!(base, dataset_fingerprint(&x2, &y, 2), "feature change");
        assert_ne!(base, dataset_fingerprint(&x, &[0, 0], 2), "label change");
        assert_ne!(
            base,
            dataset_fingerprint(&x, &[0, 1, 0, 1], 1),
            "same bytes, different shape"
        );
    }
}
