//! CLI smoke tests: run the built binary end-to-end for each subcommand
//! and assert on the output contract (not just exit codes).

use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_stiknn")
}

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(bin())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn stiknn");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn help_lists_subcommands() {
    let (stdout, _, ok) = run(&["--help"]);
    assert!(ok);
    for sub in ["value", "analyze", "ksens", "mislabel", "datasets", "artifacts"] {
        assert!(stdout.contains(sub), "help missing {sub}: {stdout}");
    }
}

#[test]
fn unknown_subcommand_fails_with_help() {
    let (_, stderr, ok) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn datasets_lists_table1() {
    let (stdout, _, ok) = run(&["datasets"]);
    assert!(ok);
    for name in ["circle", "moon", "fashionmnist", "apsfailure", "wind"] {
        assert!(stdout.contains(name), "{stdout}");
    }
}

#[test]
fn value_computes_and_writes_csv() {
    let out = std::env::temp_dir().join("stiknn_cli_phi.csv");
    let _ = std::fs::remove_file(&out);
    let (stdout, stderr, ok) = run(&[
        "value", "--dataset", "moon", "--n-train", "50", "--n-test", "12",
        "--k", "3", "--out", out.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("dataset=moon"));
    assert!(stdout.contains("throughput"));
    let text = std::fs::read_to_string(&out).unwrap();
    assert_eq!(text.lines().count(), 50, "50x50 matrix rows");
}

#[test]
fn analyze_prints_axioms_and_blocks() {
    let (stdout, stderr, ok) = run(&[
        "analyze", "--dataset", "circle", "--n-train", "80", "--n-test", "20",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("efficiency"));
    assert!(stdout.contains("OK"));
    assert!(stdout.contains("class-block structure"));
    assert!(stdout.contains("interaction heatmap"));
}

#[test]
fn ksens_reports_correlations() {
    let (stdout, stderr, ok) = run(&[
        "ksens", "--dataset", "moon", "--n-train", "60", "--n-test", "15",
        "--ks", "3,5",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("min pairwise Pearson"));
    assert!(stdout.contains("paper threshold"));
}

#[test]
fn mislabel_reports_metrics() {
    let (stdout, stderr, ok) = run(&[
        "mislabel", "--dataset", "circle", "--n-train", "100", "--n-test", "25",
        "--flip", "0.1",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("AUC"));
    assert!(stdout.contains("flipped 10 of 10"), "{stdout}"); // 100 or 101 (circle pairs)
}

#[test]
fn bad_engine_is_rejected() {
    let (_, stderr, ok) = run(&[
        "value", "--dataset", "moon", "--n-train", "20", "--n-test", "5",
        "--engine", "cuda", "--out", "-",
    ]);
    assert!(!ok);
    assert!(stderr.contains("rust or xla"));
}

#[test]
fn k_larger_than_artifact_grid_falls_back_with_clear_error() {
    // xla engine with a shape that has no artifact must tell the user how
    // to fix it (this also covers the no-artifacts-built environment)
    let (_, stderr, ok) = run(&[
        "value", "--dataset", "moon", "--n-train", "33", "--n-test", "5",
        "--engine", "xla", "--out", "-",
    ]);
    assert!(!ok);
    assert!(
        stderr.contains("make artifacts") || stderr.contains("--engine rust"),
        "unhelpful error: {stderr}"
    );
}

#[test]
fn artifacts_subcommand_lists_manifest_when_present() {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json");
    if !manifest.exists() {
        eprintln!("SKIP: no artifacts built");
        return;
    }
    let (stdout, _, ok) = run(&["artifacts"]);
    assert!(ok);
    assert!(stdout.contains("sti_n600_d2_b32_k5"));
}
