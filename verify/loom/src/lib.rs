//! Loom harness over the obs concurrency core (DESIGN.md §17).
//!
//! This crate owns **zero logic**. It `#[path]`-includes the four
//! dependency-free source files that make up stiknn-core's lock-free
//! observability core, verbatim — the same bytes the production crate
//! compiles. Built with `RUSTFLAGS="--cfg loom"`, the `sync` shim at the
//! root of that file set swaps `std::sync` for loom's model-checked
//! doubles, and the tests in `tests/models.rs` explore every
//! interleaving of the cores exhaustively.
//!
//! The inclusion works because those files reference their siblings only
//! as `use super::sync::…`, which resolves identically whether the
//! parent module is `stiknn_core::obs` or this crate root. If a `use
//! crate::…` ever sneaks into one of them, this crate stops compiling —
//! which is the desired tripwire.
//!
//! Run locally (exhaustive, no preemption bound):
//!
//! ```text
//! cd verify/loom && RUSTFLAGS="--cfg loom" cargo test --release
//! ```

#[path = "../../../crates/stiknn-core/src/obs/sync.rs"]
pub mod sync;

#[path = "../../../crates/stiknn-core/src/obs/counters.rs"]
pub mod counters;

#[path = "../../../crates/stiknn-core/src/obs/ring.rs"]
pub mod ring;

#[path = "../../../crates/stiknn-core/src/obs/slots.rs"]
pub mod slots;
