//! Exhaustive loom models for the obs concurrency core (DESIGN.md §17).
//!
//! Each test wraps a small concurrent scenario in `loom::model`, which
//! replays the body under **every** legal interleaving of its atomic
//! and lock operations (including the weak-memory value choices relaxed
//! loads permit). Assertions inside spawned threads check what a racing
//! observer may see; assertions after `join` check the quiesced state
//! exactly. The scenarios are deliberately tiny — two writers and one
//! reader — because loom's guarantee is exhaustive only when the state
//! space is; the generic cores under test are size-independent.
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"` (the CI `loom` leg);
//! without the cfg this file is empty and `cargo test` is a no-op.
#![cfg(loom)]

use loom::sync::Arc;
use loom::thread;
use stiknn_loom::counters::{Counter, Gauge, Histogram};
use stiknn_loom::ring::EventRing;
use stiknn_loom::slots::SlotRing;

/// Two writers mixing `inc` and `add`: no update is lost.
#[test]
fn counter_concurrent_writers_lose_nothing() {
    loom::model(|| {
        let c = Arc::new(Counter::new());
        let h: Vec<_> = (0..2)
            .map(|_| {
                let c = Arc::clone(&c);
                thread::spawn(move || {
                    c.inc();
                    c.add(2);
                })
            })
            .collect();
        for t in h {
            t.join().unwrap();
        }
        assert_eq!(c.get(), 6);
    });
}

/// A matched +1/−1 pair from racing threads always cancels.
#[test]
fn gauge_concurrent_deltas_cancel() {
    loom::model(|| {
        let g = Arc::new(Gauge::new());
        let up = {
            let g = Arc::clone(&g);
            thread::spawn(move || g.add(1))
        };
        let down = {
            let g = Arc::clone(&g);
            thread::spawn(move || g.add(-1))
        };
        up.join().unwrap();
        down.join().unwrap();
        assert_eq!(g.get(), 0);
    });
}

/// Two recording threads plus a racing reader. The histogram's fields
/// update independently (documented contract: readers tolerate skew),
/// so the racing reader only asserts bounds; after both writers join,
/// every field — count, sum, max, per-bucket counts, quantiles — must
/// be exact.
#[test]
fn histogram_concurrent_record_and_read() {
    loom::model(|| {
        let h = Arc::new(Histogram::new());
        let w1 = {
            let h = Arc::clone(&h);
            thread::spawn(move || h.record_ns(500))
        };
        let w2 = {
            let h = Arc::clone(&h);
            thread::spawn(move || h.record_ns(1_500))
        };
        let r = {
            let h = Arc::clone(&h);
            thread::spawn(move || {
                // Mid-flight: partial sums are fine, torn values are not.
                assert!(h.count() <= 2);
                assert!(h.sum_ns() <= 2_000);
                assert!(h.max_ns() == 0 || h.max_ns() == 500 || h.max_ns() == 1_500);
                assert!(h.quantile_ns(1.0) <= 2_000);
            })
        };
        w1.join().unwrap();
        w2.join().unwrap();
        r.join().unwrap();

        assert_eq!(h.count(), 2);
        assert_eq!(h.sum_ns(), 2_000);
        assert_eq!(h.max_ns(), 1_500);
        let buckets = h.bucket_counts();
        assert_eq!(buckets[0], 1); // 500ns -> bucket 0 (<= 1µs)
        assert_eq!(buckets[1], 1); // 1500ns -> bucket 1 (<= 2µs)
        assert_eq!(h.quantile_ns(0.5), 1_000);
        assert_eq!(h.quantile_ns(1.0), 2_000);
    });
}

/// Two writers overflowing a cap-2 event ring while a third thread
/// snapshots: sequence numbers stay unique and ordered at every
/// observable instant, and the quiesced ring holds exactly the newest
/// `cap` items with the eviction count balancing the books.
#[test]
fn event_ring_push_evict_snapshot() {
    loom::model(|| {
        let ring = Arc::new(EventRing::new(2));
        let writers: Vec<_> = (0..2)
            .map(|_| {
                let ring = Arc::clone(&ring);
                thread::spawn(move || {
                    ring.push_with(|seq| seq * 10);
                    ring.push_with(|seq| seq * 10);
                })
            })
            .collect();
        let reader = {
            let ring = Arc::clone(&ring);
            thread::spawn(move || {
                let (items, dropped) = ring.snapshot();
                assert!(items.len() <= 2);
                assert!(dropped <= 2);
                // Items are seq*10, so ordered-and-unique seqs show
                // through as strictly increasing values.
                assert!(items.windows(2).all(|w| w[0] < w[1]));
            })
        };
        for t in writers {
            t.join().unwrap();
        }
        reader.join().unwrap();

        assert_eq!(ring.pushed(), 4);
        assert_eq!(ring.dropped(), 2);
        assert_eq!(ring.seqs(), vec![2, 3]);
        assert_eq!(ring.items(), vec![20, 30]);
    });
}

/// Two writers racing the SAME slot of a cap-1 slot ring while a reader
/// collects. The ring is lossy — either writer may land last — but a
/// pair is never torn: any observed `(seq, item)` satisfies
/// `item == seq * 10`, and the claimed sequence numbers stay dense.
#[test]
fn slot_ring_same_slot_race_never_tears() {
    loom::model(|| {
        let ring = Arc::new(SlotRing::new(1));
        let writers: Vec<_> = (0..2)
            .map(|_| {
                let ring = Arc::clone(&ring);
                thread::spawn(move || {
                    ring.push_with(|seq| seq * 10);
                })
            })
            .collect();
        let reader = {
            let ring = Arc::clone(&ring);
            thread::spawn(move || {
                for (seq, item) in ring.pairs() {
                    assert!(seq < 2);
                    assert_eq!(item, seq * 10);
                }
            })
        };
        for t in writers {
            t.join().unwrap();
        }
        reader.join().unwrap();

        assert_eq!(ring.pushed(), 2);
        assert_eq!(ring.dropped(), 1);
        let pairs = ring.pairs();
        assert_eq!(pairs.len(), 1);
        let (seq, item) = pairs[0];
        // Either writer may have landed last — but never a torn mix.
        assert!(seq < 2);
        assert_eq!(item, seq * 10);
    });
}
