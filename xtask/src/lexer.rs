//! A minimal Rust surface lexer for the repo lint (DESIGN.md §17).
//!
//! The lint rules ([`crate::rules`]) are token-pattern checks, so they
//! need exactly one thing from a real parser: knowing which bytes are
//! *code* and which are string literals or comments. This module splits
//! a source file into per-line masked code (literals and comments
//! blanked to spaces, so column positions survive) plus per-line
//! comment text (for `// SAFETY:` and `// lint: allow(...)` lookups).
//!
//! Handled: line comments, nested block comments, string literals with
//! escapes, raw strings (`r"…"`, `r#"…"#`, any hash depth), byte and
//! byte-raw strings, char literals (including escapes), and the
//! char-vs-lifetime ambiguity (`'a'` vs `'a`). That is the full set of
//! Rust constructs that can make a token pattern appear where no token
//! exists.

/// One source file, split into parallel per-line views.
pub struct Masked {
    /// Code with every literal/comment byte replaced by a space.
    pub code: Vec<String>,
    /// Comment text (both `//…` and `/*…*/` bodies) per line.
    pub comments: Vec<String>,
}

impl Masked {
    /// True if the line holds no code tokens (blank or comment-only).
    pub fn is_comment_only(&self, line: usize) -> bool {
        self.code[line].trim().is_empty()
    }
}

enum State {
    Normal,
    LineComment,
    BlockComment { depth: usize },
    Str,
    RawStr { hashes: usize },
    CharLit,
}

pub fn mask(src: &str) -> Masked {
    let b: Vec<char> = src.chars().collect();
    let mut code = Vec::new();
    let mut comments = Vec::new();
    let mut code_line = String::new();
    let mut comment_line = String::new();
    let mut state = State::Normal;
    let mut prev_ident = false; // was the previous CODE char ident-ish?
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            // Line comments end here; multi-line states carry over.
            if matches!(state, State::LineComment) {
                state = State::Normal;
            }
            code.push(std::mem::take(&mut code_line));
            comments.push(std::mem::take(&mut comment_line));
            prev_ident = false;
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                if c == '/' && b.get(i + 1) == Some(&'/') {
                    state = State::LineComment;
                    code_line.push_str("  ");
                    i += 2;
                    continue;
                }
                if c == '/' && b.get(i + 1) == Some(&'*') {
                    state = State::BlockComment { depth: 1 };
                    code_line.push_str("  ");
                    i += 2;
                    continue;
                }
                if c == '"' {
                    state = State::Str;
                    code_line.push(' ');
                    i += 1;
                    continue;
                }
                // Raw / byte string starts: r" r#..." b" br" br#...",
                // only when not glued onto a preceding identifier.
                if (c == 'r' || c == 'b') && !prev_ident {
                    let mut j = i + 1;
                    if c == 'b' && b.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let raw = j > i + 1 || c == 'r';
                    let mut hashes = 0;
                    while raw && b.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if b.get(j) == Some(&'"') && (raw || c == 'b') {
                        for _ in i..=j {
                            code_line.push(' ');
                        }
                        state = if raw {
                            State::RawStr { hashes }
                        } else {
                            State::Str
                        };
                        i = j + 1;
                        prev_ident = false;
                        continue;
                    }
                }
                if c == '\'' {
                    // Char literal vs lifetime: an escape, or a closing
                    // quote two chars on, means literal (covers `b'"'`
                    // byte chars too). `'a` (no close) is a lifetime
                    // and stays as code.
                    let is_char = match b.get(i + 1) {
                        Some('\\') => true,
                        Some(_) => b.get(i + 2) == Some(&'\''),
                        None => false,
                    };
                    if is_char {
                        state = State::CharLit;
                        code_line.push(' ');
                        i += 1;
                        continue;
                    }
                }
                code_line.push(c);
                prev_ident = c.is_alphanumeric() || c == '_';
                i += 1;
            }
            State::LineComment => {
                comment_line.push(c);
                code_line.push(' ');
                i += 1;
            }
            State::BlockComment { depth } => {
                if c == '/' && b.get(i + 1) == Some(&'*') {
                    state = State::BlockComment { depth: depth + 1 };
                    code_line.push_str("  ");
                    i += 2;
                } else if c == '*' && b.get(i + 1) == Some(&'/') {
                    state = if depth == 1 {
                        State::Normal
                    } else {
                        State::BlockComment { depth: depth - 1 }
                    };
                    code_line.push_str("  ");
                    i += 2;
                } else {
                    comment_line.push(c);
                    code_line.push(' ');
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // A `\<newline>` continuation: consume only the
                    // backslash so the newline is processed normally
                    // (line counts must survive).
                    if b.get(i + 1) == Some(&'\n') {
                        code_line.push(' ');
                        i += 1;
                    } else {
                        code_line.push_str("  ");
                        i += 2;
                    }
                } else if c == '"' {
                    state = State::Normal;
                    code_line.push(' ');
                    i += 1;
                } else {
                    code_line.push(' ');
                    i += 1;
                }
            }
            State::RawStr { hashes } => {
                if c == '"' && (i + 1..=i + hashes).all(|j| b.get(j) == Some(&'#')) {
                    for _ in 0..=hashes {
                        code_line.push(' ');
                    }
                    state = State::Normal;
                    i += hashes + 1;
                } else {
                    code_line.push(' ');
                    i += 1;
                }
            }
            State::CharLit => {
                if c == '\\' {
                    code_line.push_str("  ");
                    i += 2;
                } else if c == '\'' {
                    state = State::Normal;
                    code_line.push(' ');
                    i += 1;
                } else {
                    code_line.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !code_line.is_empty() || !comment_line.is_empty() {
        code.push(code_line);
        comments.push(comment_line);
    }
    Masked { code, comments }
}

/// Does `hay` contain `needle` as a whole word (not embedded in a
/// longer identifier)? Used for token-ish matching on masked code.
pub fn has_token(hay: &str, needle: &str) -> bool {
    token_pos(hay, needle).is_some()
}

/// Byte offset of the first whole-word occurrence of `needle` in `hay`.
pub fn token_pos(hay: &str, needle: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(rel) = hay[from..].find(needle) {
        let at = from + rel;
        let before_ok = at == 0
            || !hay[..at]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        let end = at + needle.len();
        let after_ok = end >= hay.len()
            || !hay[end..]
                .chars()
                .next()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return Some(at);
        }
        from = at + needle.len().max(1);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked_but_lines_survive() {
        let m = mask("let a = \"eprintln!(x)\"; // eprintln! here\nlet b = 2;\n");
        assert_eq!(m.code.len(), 2);
        assert!(!m.code[0].contains("eprintln"));
        assert!(m.comments[0].contains("eprintln! here"));
        assert!(m.code[1].contains("let b = 2;"));
    }

    #[test]
    fn raw_strings_and_chars_are_masked() {
        let m = mask("let p = r#\"unsafe { }\"#; let c = '\"'; let l: &'a str = x;\n");
        assert!(!m.code[0].contains("unsafe"));
        // The lifetime survives as code; the char literal is blanked.
        assert!(m.code[0].contains("&'a str"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let m = mask("a /* one /* two */ still */ b\n/* open\nunsafe {\n*/ c\n");
        assert!(m.code[0].contains('a') && m.code[0].contains('b'));
        assert!(m.code[1].trim().is_empty());
        assert!(!m.code[2].contains("unsafe"));
        assert!(m.comments[2].contains("unsafe"));
        assert!(m.code[3].contains('c'));
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let m = mask("let s = \"a\\\"b unsafe c\"; call();\n");
        assert!(!m.code[0].contains("unsafe"));
        assert!(m.code[0].contains("call();"));
    }

    #[test]
    fn token_matching_respects_word_boundaries() {
        assert!(has_token("distances_into(q)", "distances_into"));
        assert!(!has_token("distances_into_kernel(q)", "distances_into"));
        assert!(!has_token("xdistances_into(q)", "distances_into"));
        assert!(has_token("x.load(Relaxed)", "load"));
        assert!(!has_token("x.overload(3)", "load"));
    }
}
