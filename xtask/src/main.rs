//! `cargo xtask` — repo automation (DESIGN.md §17).
//!
//! The only subcommand today is `lint`: walk every workspace crate's
//! sources and enforce the invariants in [`rules`]. Dependency-free on
//! purpose — the lint must run wherever the workspace builds, including
//! the offline tier-1 environment, so there is no syn/clap/walkdir.
//!
//! Exit status: 0 clean, 1 violations (printed one per line as
//! `path:line: [rule] excerpt`), 2 usage/IO errors.

mod lexer;
mod rules;

use rules::{lint_source, Scope, Violation};
use std::path::{Path, PathBuf};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("lint") => cmd_lint(&args[1..]),
        _ => {
            eprintln!("usage: cargo xtask lint [--root DIR]");
            2
        }
    };
    std::process::exit(code);
}

/// Per-file rule scope (the policy layer over [`rules::lint_source`]):
///
/// * binary/tooling crates (`stiknn-cli`, `xtask`) keep console output
///   and ad-hoc timing — library discipline off;
/// * `knn/` IS the distance implementation — `raw-distance` off there;
/// * `obs/` IS the clock — `raw-clock` off there;
/// * everything else gets the full set.
fn scope_of(rel: &str) -> Scope {
    let tooling = rel.starts_with("crates/stiknn-cli/") || rel.starts_with("xtask/");
    Scope {
        library: !tooling,
        distance: !tooling && !rel.starts_with("crates/stiknn-core/src/knn/"),
        clock: !tooling && !rel.starts_with("crates/stiknn-core/src/obs/"),
    }
}

fn cmd_lint(args: &[String]) -> i32 {
    let mut root = workspace_root();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => {
                    eprintln!("--root needs a directory");
                    return 2;
                }
            },
            other => {
                eprintln!("unknown lint flag '{other}'");
                return 2;
            }
        }
    }

    let mut files = Vec::new();
    for dir in ["crates", "xtask/src"] {
        collect_rs(&root.join(dir), &mut files);
    }
    files.sort();

    let mut violations: Vec<Violation> = Vec::new();
    let mut scanned = 0usize;
    for path in &files {
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        // Lint crate sources; leave integration tests, benches and
        // examples to their own idioms.
        let in_src = rel.contains("/src/") || rel.starts_with("xtask/src/");
        if !in_src {
            continue;
        }
        let src = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("xtask lint: cannot read {rel}: {e}");
                return 2;
            }
        };
        scanned += 1;
        violations.extend(lint_source(&rel, &src, scope_of(&rel)));
    }

    if violations.is_empty() {
        println!("xtask lint: OK ({scanned} files, 6 rules)");
        0
    } else {
        for v in &violations {
            println!("{}:{}: [{}] {}", v.path, v.line, v.rule, v.excerpt);
        }
        println!("xtask lint: {} violation(s)", violations.len());
        1
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            let name = entry.file_name();
            if name != "target" {
                collect_rs(&path, out);
            }
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// The workspace root: walk up from this binary's manifest directory
/// (compile-time, so `cargo xtask` works from any subdirectory).
fn workspace_root() -> PathBuf {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest
        .parent()
        .map(Path::to_path_buf)
        .unwrap_or(manifest)
}
