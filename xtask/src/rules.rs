//! The repo lint rules (`cargo xtask lint`, DESIGN.md §17).
//!
//! Each rule encodes an invariant this codebase previously enforced by
//! review alone:
//!
//! | rule                  | invariant                                                  |
//! |-----------------------|------------------------------------------------------------|
//! | `partial-cmp-unwrap`  | float ordering goes through `total_cmp` (+ index tiebreak) |
//! | `bare-eprintln`       | library crates log via `ObsHandle::event_logged`           |
//! | `undocumented-unsafe` | every `unsafe` carries a `SAFETY:` / `# Safety` rationale  |
//! | `implicit-ordering`   | every atomic op names its `Ordering` explicitly            |
//! | `raw-distance`        | distance math goes through the kernel dispatch             |
//! | `raw-clock`           | timestamps go through `obs::now()`                         |
//!
//! Escape hatch: a `// lint: allow(<rule>)` comment on the same line or
//! in the comment block directly above the flagged line, stating why
//! the exception is deliberate. Scoping (which crates/rules pair up,
//! and the kernel/obs home directories where the raw calls ARE the
//! implementation) lives in [`crate::main`]'s file walk.

use crate::lexer::{has_token, mask, token_pos, Masked};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub path: String,
    pub line: usize, // 1-based
    pub rule: &'static str,
    pub excerpt: String,
}

/// Which rule families apply to a file (decided by the caller from the
/// file's path — see `scope_of` in main.rs).
#[derive(Clone, Copy)]
pub struct Scope {
    /// Library-crate discipline: bare-eprintln, raw-clock.
    pub library: bool,
    /// Distance calls must use the kernel (off inside knn/ itself).
    pub distance: bool,
    /// Clock reads must use obs::now (off inside obs/ itself).
    pub clock: bool,
}

const ATOMIC_METHODS: [&str; 12] = [
    "load",
    "store",
    "fetch_add",
    "fetch_sub",
    "fetch_and",
    "fetch_or",
    "fetch_xor",
    "fetch_max",
    "fetch_min",
    "fetch_update",
    "compare_exchange",
    "compare_exchange_weak",
];

const ORDERING_TOKENS: [&str; 6] = [
    "Relaxed", "Acquire", "Release", "AcqRel", "SeqCst", "Ordering",
];

/// Lint one file's source. `path` is used for labels only — scoping is
/// the caller's `scope` — so this is directly unit-testable on fixture
/// snippets.
pub fn lint_source(path: &str, src: &str, scope: Scope) -> Vec<Violation> {
    let m = mask(src);
    let in_test = test_block_lines(&m);
    let mut out = Vec::new();
    let mut flag = |line: usize, rule: &'static str, src_lines: &[&str]| {
        if !allowed(&m, line, rule) {
            out.push(Violation {
                path: path.to_string(),
                line: line + 1,
                rule,
                excerpt: src_lines.get(line).map_or("", |l| l.trim()).to_string(),
            });
        }
    };
    let src_lines: Vec<&str> = src.lines().collect();

    for (i, code) in m.code.iter().enumerate() {
        let tests = in_test[i];

        // partial-cmp-unwrap: any .partial_cmp( use. The clippy
        // disallowed-methods list bans it too; this copy runs offline
        // with the plain toolchain. (Applies in tests as well: tests
        // set the conventions the next reader copies.)
        if code.contains(".partial_cmp(") {
            flag(i, "partial-cmp-unwrap", &src_lines);
        }

        // bare-eprintln: library crates must route operational output
        // through ObsHandle::event_logged so every log line has a
        // structured twin in the event ring.
        if scope.library && !tests && code.contains("eprintln!") {
            flag(i, "bare-eprintln", &src_lines);
        }

        // undocumented-unsafe: every unsafe block/fn carries a nearby
        // SAFETY rationale (comment may sit above attributes).
        if has_token(code, "unsafe") && !safety_documented(&m, i) {
            flag(i, "undocumented-unsafe", &src_lines);
        }

        // implicit-ordering: atomic calls must name their Ordering in
        // the argument list (no default-SeqCst helpers drifting in).
        if atomic_call_without_ordering(&m, i).is_some() {
            flag(i, "implicit-ordering", &src_lines);
        }

        // raw-distance: the scalar reference loop bypasses the SIMD
        // dispatch; everything but knn/ itself and marked oracles must
        // call distances_into_kernel / distances_block. Only CALLS
        // count — `use` imports of the symbol are fine.
        if scope.distance && !tests && is_called(code, "distances_into") {
            flag(i, "raw-distance", &src_lines);
        }

        // raw-clock: timestamps go through obs::now() so there is one
        // auditable clock seam.
        if scope.clock && !tests && code.contains("Instant::now") {
            flag(i, "raw-clock", &src_lines);
        }
    }
    out
}

/// Is `name` used as a call on this line (token followed by `(`)?
fn is_called(code: &str, name: &str) -> bool {
    match token_pos(code, name) {
        Some(at) => code[at + name.len()..].trim_start().starts_with('('),
        None => false,
    }
}

/// Is a `// lint: allow(rule)` marker on the flagged line or in the
/// contiguous comment block directly above it?
fn allowed(m: &Masked, line: usize, rule: &str) -> bool {
    let needle = format!("lint: allow({rule})");
    if m.comments[line].contains(&needle) {
        return true;
    }
    let mut j = line;
    while j > 0 && line - j < 10 {
        j -= 1;
        if !m.is_comment_only(j) {
            return false;
        }
        if m.comments[j].contains(&needle) {
            return true;
        }
    }
    false
}

/// Is a SAFETY rationale within the 10 lines above (or on) `line`?
/// Unlike [`allowed`], attributes and the `unsafe` line itself may sit
/// between the comment and the flagged line — rustdoc `# Safety`
/// sections precede `#[target_feature]` attributes.
fn safety_documented(m: &Masked, line: usize) -> bool {
    let lo = line.saturating_sub(10);
    (lo..=line).any(|j| m.comments[j].contains("SAFETY:") || m.comments[j].contains("# Safety"))
}

/// Find an atomic-method call on `line` whose argument list (up to 4
/// lines, for rustfmt-wrapped calls) contains no Ordering token.
fn atomic_call_without_ordering(m: &Masked, line: usize) -> Option<&'static str> {
    let code = &m.code[line];
    for method in ATOMIC_METHODS {
        let pat = format!(".{method}(");
        let Some(at) = code.find(&pat) else { continue };
        // Word-boundary check on the method name (".load(" can suffix
        // ".overload(" textually).
        if token_pos(&code[at + 1..], method) != Some(0) {
            continue;
        }
        let open = at + pat.len() - 1;
        let mut args = String::new();
        let mut depth = 0usize;
        'scan: for (li, text) in m.code.iter().enumerate().skip(line).take(4) {
            let s = if li == line { &text[open..] } else { &text[..] };
            for c in s.chars() {
                match c {
                    '(' => depth += 1,
                    ')' => {
                        depth -= 1;
                        if depth == 0 {
                            break 'scan;
                        }
                    }
                    _ => {}
                }
                if depth > 0 {
                    args.push(c);
                }
            }
            args.push(' ');
        }
        if !ORDERING_TOKENS.iter().any(|t| has_token(&args, t)) {
            return Some(method);
        }
    }
    None
}

/// Mark every line inside `#[cfg(test)] mod … { … }` blocks, by brace
/// matching on masked code. Test modules keep their own idioms (oracle
/// distance loops, raw timing in assertions) without markers.
fn test_block_lines(m: &Masked) -> Vec<bool> {
    let mut flags = vec![false; m.code.len()];
    let mut i = 0;
    while i < m.code.len() {
        if m.code[i].contains("#[cfg(test)]") {
            // Find the mod line, then brace-match to its end.
            let mut j = i;
            while j < m.code.len() && !has_token(&m.code[j], "mod") {
                j += 1;
            }
            let mut depth = 0usize;
            let mut started = false;
            while j < m.code.len() {
                for c in m.code[j].chars() {
                    match c {
                        '{' => {
                            depth += 1;
                            started = true;
                        }
                        '}' => depth = depth.saturating_sub(1),
                        _ => {}
                    }
                }
                flags[j] = true;
                if started && depth == 0 {
                    break;
                }
                j += 1;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    flags
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: Scope = Scope {
        library: true,
        distance: true,
        clock: true,
    };

    fn rules_hit(src: &str) -> Vec<&'static str> {
        lint_source("fixture.rs", src, ALL)
            .into_iter()
            .map(|v| v.rule)
            .collect()
    }

    #[test]
    fn clean_source_passes() {
        let src = r#"
            pub fn tidy(xs: &mut [f64]) {
                xs.sort_by(|a, b| a.total_cmp(b));
                let t0 = crate::obs::now();
                let _ = t0;
            }
        "#;
        assert!(rules_hit(src).is_empty());
    }

    #[test]
    fn partial_cmp_is_flagged_and_reported_with_position() {
        let src = "fn f(a: f64, b: f64) {\n    let _ = a.partial_cmp(&b).unwrap();\n}\n";
        let v = lint_source("fixture.rs", src, ALL);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "partial-cmp-unwrap");
        assert_eq!(v[0].line, 2);
        assert!(v[0].excerpt.contains("partial_cmp"));
    }

    #[test]
    fn bare_eprintln_flagged_only_in_library_scope() {
        let src = "fn f() {\n    eprintln!(\"boom\");\n}\n";
        assert_eq!(rules_hit(src), vec!["bare-eprintln"]);
        let bin = Scope {
            library: false,
            ..ALL
        };
        assert!(lint_source("fixture.rs", src, bin).is_empty());
    }

    #[test]
    fn eprintln_in_strings_comments_and_tests_is_ignored() {
        let src = r#"
            fn f() {
                let tip = "try eprintln!(x)"; // or eprintln! by hand
                let _ = tip;
            }
            #[cfg(test)]
            mod tests {
                #[test]
                fn t() {
                    eprintln!("test diagnostics are fine");
                    let _ = std::time::Instant::now();
                }
            }
        "#;
        assert!(rules_hit(src).is_empty());
    }

    #[test]
    fn undocumented_unsafe_flagged_documented_passes() {
        let bad = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        assert_eq!(rules_hit(bad), vec!["undocumented-unsafe"]);

        let good = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n";
        assert!(rules_hit(good).is_empty());

        // Rustdoc `# Safety` above attributes also counts.
        let attr = "/// # Safety\n/// Caller must check avx2.\n#[target_feature(enable = \"avx2\")]\nunsafe fn g() {}\n";
        assert!(rules_hit(attr).is_empty());
    }

    #[test]
    fn atomic_without_ordering_flagged_explicit_passes() {
        let bad = "fn f(a: &AtomicU64) -> u64 {\n    a.fetch_add(1);\n    a.load()\n}\n";
        assert_eq!(
            rules_hit(bad),
            vec!["implicit-ordering", "implicit-ordering"]
        );

        let good = "fn f(a: &AtomicU64) -> u64 {\n    a.fetch_add(1, Ordering::Relaxed);\n    a.load(Relaxed)\n}\n";
        assert!(rules_hit(good).is_empty());

        // Wrapped across lines (rustfmt style) still resolves: the
        // argument scan window reaches the Ordering on line 4.
        let wrapped =
            "fn f(a: &AtomicU64) {\n    a.compare_exchange_weak(\n        0,\n        1,\n        Ordering::AcqRel,\n        Ordering::Relaxed,\n    );\n}\n";
        assert!(rules_hit(wrapped).is_empty());

        // Non-atomic .store( on some other type must name its ordering
        // or get a marker — the rule is textual by design.
        let other = "fn f(s: &Store) {\n    s.store(5);\n}\n";
        assert_eq!(rules_hit(other), vec!["implicit-ordering"]);
    }

    #[test]
    fn raw_distance_and_raw_clock_flagged_in_scope() {
        let src = "fn f() {\n    distances_into(q, x, d, m, &mut out);\n    let t = std::time::Instant::now();\n}\n";
        assert_eq!(rules_hit(src), vec!["raw-distance", "raw-clock"]);
        // Kernel twin never matches the distance token.
        let kernel = "fn f() {\n    distances_into_kernel(q, x, d, m, &n, &mut out);\n}\n";
        assert!(rules_hit(kernel).is_empty());
        // Home-directory scopes turn the rules off.
        let home = Scope {
            distance: false,
            clock: false,
            ..ALL
        };
        assert!(lint_source("fixture.rs", src, home).is_empty());
    }

    #[test]
    fn allow_markers_suppress_same_line_and_comment_block_above() {
        let same = "fn f() {\n    eprintln!(\"x\"); // lint: allow(bare-eprintln) — operator console\n}\n";
        assert!(rules_hit(same).is_empty());

        let above = "fn f() {\n    // lint: allow(raw-clock) — measuring the clock itself\n    // (second comment line between marker and code is fine)\n    let t = Instant::now();\n}\n";
        assert!(rules_hit(above).is_empty());

        // The marker names ONE rule; others on the line still fire.
        let wrong = "fn f() {\n    // lint: allow(raw-clock)\n    eprintln!(\"x\");\n}\n";
        assert_eq!(rules_hit(wrong), vec!["bare-eprintln"]);

        // A marker does not leak past intervening code.
        let stale = "fn f() {\n    // lint: allow(bare-eprintln)\n    let x = 1;\n    eprintln!(\"{x}\");\n}\n";
        assert_eq!(rules_hit(stale), vec!["bare-eprintln"]);
    }

    #[test]
    fn seeded_violations_in_realistic_snippet_all_fire() {
        // The acceptance fixture: one snippet seeding every rule.
        let src = r#"
            fn seeded(a: &AtomicU64, xs: &mut [f64]) {
                xs.sort_by(|p, q| p.partial_cmp(q).unwrap());
                eprintln!("oops");
                let _ = unsafe { *xs.as_ptr() };
                a.fetch_add(1);
                distances_into(q, x, d, m, &mut out);
                let _t = std::time::Instant::now();
            }
        "#;
        let mut rules = rules_hit(src);
        rules.sort();
        assert_eq!(
            rules,
            vec![
                "bare-eprintln",
                "implicit-ordering",
                "partial-cmp-unwrap",
                "raw-clock",
                "raw-distance",
                "undocumented-unsafe",
            ]
        );
    }
}
